//! The experiment registry: one [`Figure`] per figure of the paper's
//! evaluation (see DESIGN.md §4 for the index).

use crate::baselines::Library;
use crate::gen::Workload;
use crate::kernels::classic::pure_classic;
use crate::kernels::gustavson::pure_row_major;
use crate::kernels::tracer::NullTracer;
use crate::kernels::{spmmm, Strategy};
use crate::sparse::convert::csc_to_csr;
use crate::sparse::{CscMatrix, CsrMatrix};
use crate::util::timer::black_box;

/// One benchmark series (a curve in a figure).
#[derive(Clone, Copy, Debug)]
pub enum SeriesKind {
    /// Pure computation, row-major Gustavson, CSR × CSR (Listing 2).
    PureRowMajor,
    /// Pure computation where the CSC right-hand side is converted to
    /// CSR inside the timed region ("CSR × CSC (with conversion)").
    PureConvThenRowMajor,
    /// Pure computation, classic CSR × CSC dot-product kernel.
    PureClassic,
    /// Full spMMM (compute + store) with a storing strategy, CSR × CSR.
    Full(Strategy),
    /// Full spMMM CSR × CSC: conversion + strategy, timed together.
    FullConv(Strategy),
    /// A library's CSR = CSR × CSR product (Figures 9/10).
    LibCsrCsr(Library),
    /// A library's CSR = CSR × CSC product (Figures 11/12).
    LibCsrCsc(Library),
}

impl SeriesKind {
    /// Legend label (paper naming).
    pub fn label(&self) -> String {
        match self {
            SeriesKind::PureRowMajor => "row-major (CSR x CSR)".into(),
            SeriesKind::PureConvThenRowMajor => "CSR x CSC (with conversion)".into(),
            SeriesKind::PureClassic => "classic (CSR x CSC)".into(),
            SeriesKind::Full(s) => s.name().into(),
            SeriesKind::FullConv(s) => format!("{} (conv)", s.name()),
            SeriesKind::LibCsrCsr(l) | SeriesKind::LibCsrCsc(l) => l.name().into(),
        }
    }

    /// Execute once on prepared operands (`b_csc` is the converted copy
    /// of `b`, prepared outside the timed region for the series that
    /// *receive* a CSC operand).
    pub fn execute(&self, a: &CsrMatrix, b: &CsrMatrix, b_csc: &CscMatrix) {
        match self {
            SeriesKind::PureRowMajor => {
                black_box(pure_row_major(a, b, &mut NullTracer));
            }
            SeriesKind::PureConvThenRowMajor => {
                let b_conv = csc_to_csr(b_csc);
                black_box(pure_row_major(a, &b_conv, &mut NullTracer));
            }
            SeriesKind::PureClassic => {
                black_box(pure_classic(a, b_csc, &mut NullTracer));
            }
            SeriesKind::Full(s) => {
                black_box(spmmm(a, b, *s));
            }
            SeriesKind::FullConv(s) => {
                let b_conv = csc_to_csr(b_csc);
                black_box(spmmm(a, &b_conv, *s));
            }
            SeriesKind::LibCsrCsr(l) => {
                black_box(l.multiply_csr_csr(a, b));
            }
            SeriesKind::LibCsrCsc(l) => {
                black_box(l.multiply_csr_csc(a, b_csc));
            }
        }
    }

    /// Largest N this series stays tractable at (the classic and
    /// uBLAS-like kernels have N²-ish cost and must be capped, as in the
    /// paper where they stop registering beyond small N).
    pub fn max_feasible_n(&self, full: bool) -> usize {
        let quad_cap = if full { 20_000 } else { 5_000 };
        match self {
            SeriesKind::PureClassic => quad_cap,
            SeriesKind::LibCsrCsr(Library::UblasLike) => quad_cap,
            SeriesKind::LibCsrCsc(Library::UblasLike) => quad_cap,
            _ => usize::MAX,
        }
    }
}

/// A paper figure: workload, size sweep, and the series it compares.
#[derive(Clone, Debug)]
pub struct Figure {
    /// Paper figure number (2..=12).
    pub id: u32,
    /// Title (paper caption, abbreviated).
    pub title: &'static str,
    /// Workload family.
    pub workload: Workload,
    /// Series compared.
    pub series: Vec<SeriesKind>,
    /// Problem sizes (N = rows); quick sweep.
    pub sizes_quick: Vec<usize>,
    /// Problem sizes for `BLAZEMARK_FULL=1` (paper-scale).
    pub sizes_full: Vec<usize>,
}

impl Figure {
    /// The size sweep for the given mode.
    pub fn sizes(&self, full: bool) -> &[usize] {
        if full {
            &self.sizes_full
        } else {
            &self.sizes_quick
        }
    }
}

/// Geometric sweep used by most figures.
fn sweep(max: usize) -> Vec<usize> {
    let mut v = vec![64usize, 144, 324, 784, 1764, 4096, 9216, 20736, 46656, 104976, 236196, 531441, 1048576];
    v.retain(|&n| n <= max);
    v
}

/// Build the registry (Figures 2-12).
pub fn build_figures() -> Vec<Figure> {
    use SeriesKind::*;
    let pure = vec![PureRowMajor, PureConvThenRowMajor, PureClassic];
    let store4 = vec![
        Full(Strategy::BruteForceDouble),
        Full(Strategy::BruteForceBool),
        Full(Strategy::BruteForceChar),
        Full(Strategy::MinMax),
        Full(Strategy::MinMaxChar),
    ];
    let sortcmp = vec![Full(Strategy::MinMax), Full(Strategy::Sort), Full(Strategy::Combined)];
    let libs_rr: Vec<SeriesKind> = Library::ALL.iter().map(|&l| LibCsrCsr(l)).collect();
    let libs_rc: Vec<SeriesKind> = Library::ALL.iter().map(|&l| LibCsrCsc(l)).collect();

    vec![
        Figure {
            id: 2,
            title: "Pure computation (FD); memory model limit 1140 MFlop/s",
            workload: Workload::FiveBandFd,
            series: pure.clone(),
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 3,
            title: "Pure computation (random)",
            workload: Workload::RandomFixed5,
            series: pure,
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 4,
            title: "Brute Force vs MinMax kernels (FD), complete spMMM",
            workload: Workload::FiveBandFd,
            series: store4.clone(),
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 5,
            title: "Brute Force vs MinMax kernels (random), complete spMMM",
            workload: Workload::RandomFixed5,
            series: store4,
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 6,
            title: "MinMax vs Sort (FD), complete spMMM",
            workload: Workload::FiveBandFd,
            series: sortcmp.clone(),
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 7,
            title: "MinMax vs Sort (random); switch between N=49 and N=64",
            workload: Workload::RandomFixed5,
            series: sortcmp.clone(),
            // The paper zooms into small N here to show the Combined
            // switch; include the small range explicitly.
            sizes_quick: vec![16, 25, 36, 49, 64, 100, 256, 1024, 4096, 16384],
            sizes_full: vec![16, 25, 36, 49, 64, 100, 256, 1024, 4096, 16384, 65536, 262144],
        },
        Figure {
            id: 8,
            title: "MinMax vs Sort, random 0.1% fill; crossover near N=38000",
            workload: Workload::RandomFill01Pct,
            series: sortcmp,
            sizes_quick: vec![4000, 8000, 16000, 24000, 32000, 40000, 48000],
            sizes_full: vec![4000, 8000, 16000, 24000, 32000, 38000, 44000, 52000, 64000, 80000],
        },
        Figure {
            id: 9,
            title: "Library comparison CSR = CSR x CSR (FD)",
            workload: Workload::FiveBandFd,
            series: libs_rr.clone(),
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 10,
            title: "Library comparison CSR = CSR x CSR (random)",
            workload: Workload::RandomFixed5,
            series: libs_rr,
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 11,
            title: "Library comparison CSR = CSR x CSC (FD)",
            workload: Workload::FiveBandFd,
            series: libs_rc.clone(),
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
        Figure {
            id: 12,
            title: "Library comparison CSR = CSR x CSC (random)",
            workload: Workload::RandomFixed5,
            series: libs_rc,
            sizes_quick: sweep(50_000),
            sizes_full: sweep(1_100_000),
        },
    ]
}

/// All figures (lazily built, immutable).
pub static FIGURES: std::sync::LazyLock<Vec<Figure>> = std::sync::LazyLock::new(build_figures);

/// Find a figure by its paper number.
pub fn figure_by_id(id: u32) -> Option<&'static Figure> {
    FIGURES.iter().find(|f| f.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::operand_pair;
    use crate::sparse::convert::csr_to_csc;

    #[test]
    fn registry_covers_figures_2_to_12() {
        let ids: Vec<u32> = FIGURES.iter().map(|f| f.id).collect();
        assert_eq!(ids, (2..=12).collect::<Vec<_>>());
    }

    #[test]
    fn every_series_executes() {
        for fig in FIGURES.iter() {
            let n = fig.sizes_quick[0].min(100);
            let (a, b) = operand_pair(fig.workload, n, 1);
            let b_csc = csr_to_csc(&b);
            for s in &fig.series {
                s.execute(&a, &b, &b_csc);
            }
        }
    }

    #[test]
    fn labels_unique_within_figure() {
        for fig in FIGURES.iter() {
            let mut labels: Vec<String> = fig.series.iter().map(|s| s.label()).collect();
            labels.sort();
            let before = labels.len();
            labels.dedup();
            assert_eq!(before, labels.len(), "figure {}", fig.id);
        }
    }

    #[test]
    fn caps_apply_to_quadratic_series() {
        assert!(SeriesKind::PureClassic.max_feasible_n(false) < 10_000);
        assert_eq!(SeriesKind::PureRowMajor.max_feasible_n(false), usize::MAX);
    }

    #[test]
    fn sweep_is_increasing_and_capped() {
        let s = sweep(100_000);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(*s.last().unwrap() <= 100_000);
    }
}
