//! The Blazemark benchmark harness (paper §III).
//!
//! Methodology reproduced from the paper:
//!
//! * the same seed drives the matrix generation for *all* compared
//!   kernels/libraries — every series of a figure operates on the same
//!   matrix objects;
//! * "short test-cases [run] several times until the total runtime
//!   exceeds two seconds", each test is performed at least 5 times, and
//!   the best result is the measurement ([`runner`]);
//! * MFlop/s is computed from the worst-case flop count
//!   2 × Σ ā_k b̄_k ([`crate::kernels::flops::spmmm_flops`]), *not* from
//!   the work the specific kernel happens to do;
//! * conversion costs (CSR ↔ CSC) are timed inside the kernel region for
//!   the "with conversion" series, exactly as in Figures 2/3/11/12.
//!
//! Because the full two-second/5-trial protocol over eleven figures takes
//! hours, the default configuration scales it down (50 ms minimum, 3
//! trials) and `BLAZEMARK_FULL=1` restores the paper's numbers. Either
//! way the *protocol shape* (adaptive repetition, best-of) is identical.
//!
//! [`figures`] holds the experiment registry: one entry per paper figure,
//! mapping to the kernels/baselines it compares; `cargo bench` exposes
//! each as its own target (`rust/benches/figNN_*.rs`).

pub mod figures;
pub mod report;
pub mod runner;

pub use figures::{figure_by_id, Figure, SeriesKind, FIGURES};
pub use report::{row_field, run_figure, BenchRecord, BenchRow, FigureResult, BENCH_SCHEMA};
pub use runner::{
    measure, BenchConfig, ChainAccounting, Measurement, Pipeline, PipelineAccounting, PlanMode,
    SweepSession,
};
