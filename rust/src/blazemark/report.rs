//! Figure execution + reporting: runs every series of a figure over the
//! size sweep and prints the same rows/series the paper's figures plot.

use super::figures::Figure;
use super::runner::{measure, BenchConfig};
use crate::gen::operand_pair;
use crate::kernels::flops::spmmm_flops;
use crate::sparse::convert::csr_to_csc;
use crate::sparse::SparseShape;
use crate::util::table::{ascii_chart, Table};

/// The measured curves of one figure.
#[derive(Clone, Debug)]
pub struct FigureResult {
    /// Paper figure number.
    pub id: u32,
    /// Caption.
    pub title: String,
    /// Series names, figure order.
    pub series_names: Vec<String>,
    /// `(N, [mflops_per_series])`; a series skipped at a size (cap)
    /// holds `None`.
    pub rows: Vec<(usize, Vec<Option<f64>>)>,
}

impl FigureResult {
    /// Aligned table, one row per N, one column per series.
    pub fn render_table(&self) -> String {
        let mut header = vec!["N".to_string()];
        header.extend(self.series_names.iter().cloned());
        let mut t = Table::new(header);
        for (n, vals) in &self.rows {
            let mut row = vec![n.to_string()];
            for v in vals {
                row.push(match v {
                    Some(m) => format!("{m:.1}"),
                    None => "-".to_string(),
                });
            }
            t.row(row);
        }
        format!("Figure {} — {} (MFlop/s, higher is better)\n{}", self.id, self.title, t.render())
    }

    /// ASCII chart of the curves.
    pub fn render_chart(&self) -> String {
        let series: Vec<(String, Vec<(f64, f64)>)> = self
            .series_names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let pts = self
                    .rows
                    .iter()
                    .filter_map(|(n, vals)| vals[i].map(|m| (*n as f64, m)))
                    .collect();
                (name.clone(), pts)
            })
            .collect();
        ascii_chart(&series, 72, 18)
    }

    /// CSV (one row per N; series columns).
    pub fn to_csv(&self) -> String {
        let mut header = vec!["n".to_string()];
        header.extend(self.series_names.iter().cloned());
        let mut t = Table::new(header);
        for (n, vals) in &self.rows {
            let mut row = vec![n.to_string()];
            for v in vals {
                row.push(v.map(|m| format!("{m:.3}")).unwrap_or_default());
            }
            t.row(row);
        }
        t.to_csv()
    }

    /// Write the CSV under `results/`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::PathBuf::from(format!("results/fig{:02}.csv", self.id));
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Run one figure under the given protocol. `seed` feeds the workload
/// generator (all series share operands). Progress lines go to stderr so
/// stdout stays machine-readable.
pub fn run_figure(fig: &Figure, cfg: &BenchConfig, seed: u64, verbose: bool) -> FigureResult {
    let full = cfg.min_time_s >= 1.0;
    let mut rows = Vec::new();
    for &n in fig.sizes(full) {
        let (a, b) = operand_pair(fig.workload, n, seed);
        let b_csc = csr_to_csc(&b);
        let flops = spmmm_flops(&a, &b);
        let mut vals = Vec::with_capacity(fig.series.len());
        for s in &fig.series {
            if a.rows() > s.max_feasible_n(full) {
                vals.push(None);
                continue;
            }
            let m = measure(cfg, || s.execute(&a, &b, &b_csc));
            let mflops = m.mflops(flops);
            if verbose {
                eprintln!(
                    "  fig{:02} N={:<8} {:<28} {:>10.1} MFlop/s ({} reps x {} trials)",
                    fig.id,
                    a.rows(),
                    s.label(),
                    mflops,
                    m.reps,
                    m.trials
                );
            }
            vals.push(Some(mflops));
        }
        rows.push((a.rows(), vals));
    }
    FigureResult {
        id: fig.id,
        title: fig.title.to_string(),
        series_names: fig.series.iter().map(|s| s.label()).collect(),
        rows,
    }
}

/// Entry point shared by the `rust/benches/figNN_*.rs` targets: run one
/// figure with the env-configured protocol, print table + chart, write
/// the CSV.
pub fn bench_main(figure_id: u32) {
    let fig = super::figures::figure_by_id(figure_id)
        .unwrap_or_else(|| panic!("unknown figure {figure_id}"));
    let cfg = BenchConfig::from_env();
    eprintln!(
        "blazemark figure {} [{}] — min_time={}s trials={} (BLAZEMARK_FULL=1 for paper protocol)",
        fig.id,
        fig.workload.tag(),
        cfg.min_time_s,
        cfg.trials
    );
    let res = run_figure(fig, &cfg, 0xb1a2e, true);
    println!("{}", res.render_table());
    println!("{}", res.render_chart());
    match res.write_csv() {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blazemark::figures::figure_by_id;

    fn tiny_cfg() -> BenchConfig {
        BenchConfig { min_time_s: 0.0005, trials: 1 }
    }

    #[test]
    fn run_figure_2_smoke() {
        let mut fig = figure_by_id(2).unwrap().clone();
        fig.sizes_quick = vec![64, 256];
        let res = run_figure(&fig, &tiny_cfg(), 1, false);
        assert_eq!(res.rows.len(), 2);
        assert_eq!(res.series_names.len(), 3);
        for (_, vals) in &res.rows {
            for v in vals {
                assert!(v.unwrap() > 0.0);
            }
        }
        let table = res.render_table();
        assert!(table.contains("Figure 2"));
        let csv = res.to_csv();
        assert!(csv.lines().count() == 3);
    }

    #[test]
    fn caps_show_as_none() {
        let mut fig = figure_by_id(9).unwrap().clone();
        fig.sizes_quick = vec![9216]; // above the quick uBLAS cap (5000)
        let res = run_figure(&fig, &tiny_cfg(), 1, false);
        let ublas_idx = res.series_names.iter().position(|n| n.contains("uBLAS")).unwrap();
        assert!(res.rows[0].1[ublas_idx].is_none());
        let blaze_idx = res.series_names.iter().position(|n| n == "Blaze").unwrap();
        assert!(res.rows[0].1[blaze_idx].is_some());
    }

    #[test]
    fn chart_renders() {
        let mut fig = figure_by_id(6).unwrap().clone();
        fig.sizes_quick = vec![64, 144];
        let res = run_figure(&fig, &tiny_cfg(), 1, false);
        let chart = res.render_chart();
        assert!(chart.contains("MFlop/s"));
    }
}
