//! Figure execution + reporting: runs every series of a figure over the
//! size sweep and prints the same rows/series the paper's figures plot —
//! plus the one versioned structured-record schema ([`BenchRecord`],
//! `blazert-bench-v1`) every bench and experiment emits, replacing the
//! per-bench hand-rolled `BENCH_*.json` shapes.

use super::figures::Figure;
use super::runner::{measure, BenchConfig};
use crate::gen::operand_pair;
use crate::kernels::flops::spmmm_flops;
use crate::sparse::convert::csr_to_csc;
use crate::sparse::SparseShape;
use crate::util::json::Json;
use crate::util::table::{ascii_chart, Table};

/// The measured curves of one figure.
#[derive(Clone, Debug)]
pub struct FigureResult {
    /// Paper figure number.
    pub id: u32,
    /// Caption.
    pub title: String,
    /// Series names, figure order.
    pub series_names: Vec<String>,
    /// `(N, [mflops_per_series])`; a series skipped at a size (cap)
    /// holds `None`.
    pub rows: Vec<(usize, Vec<Option<f64>>)>,
}

impl FigureResult {
    /// Aligned table, one row per N, one column per series.
    pub fn render_table(&self) -> String {
        let mut header = vec!["N".to_string()];
        header.extend(self.series_names.iter().cloned());
        let mut t = Table::new(header);
        for (n, vals) in &self.rows {
            let mut row = vec![n.to_string()];
            for v in vals {
                row.push(match v {
                    Some(m) => format!("{m:.1}"),
                    None => "-".to_string(),
                });
            }
            t.row(row);
        }
        format!("Figure {} — {} (MFlop/s, higher is better)\n{}", self.id, self.title, t.render())
    }

    /// ASCII chart of the curves.
    pub fn render_chart(&self) -> String {
        let series: Vec<(String, Vec<(f64, f64)>)> = self
            .series_names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let pts = self
                    .rows
                    .iter()
                    .filter_map(|(n, vals)| vals[i].map(|m| (*n as f64, m)))
                    .collect();
                (name.clone(), pts)
            })
            .collect();
        ascii_chart(&series, 72, 18)
    }

    /// CSV (one row per N; series columns).
    pub fn to_csv(&self) -> String {
        let mut header = vec!["n".to_string()];
        header.extend(self.series_names.iter().cloned());
        let mut t = Table::new(header);
        for (n, vals) in &self.rows {
            let mut row = vec![n.to_string()];
            for v in vals {
                row.push(v.map(|m| format!("{m:.3}")).unwrap_or_default());
            }
            t.row(row);
        }
        t.to_csv()
    }

    /// Write the CSV under `results/`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        let path = std::path::PathBuf::from(format!("results/fig{:02}.csv", self.id));
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Run one figure under the given protocol. `seed` feeds the workload
/// generator (all series share operands). Progress lines go to stderr so
/// stdout stays machine-readable.
pub fn run_figure(fig: &Figure, cfg: &BenchConfig, seed: u64, verbose: bool) -> FigureResult {
    let full = cfg.min_time_s >= 1.0;
    let mut rows = Vec::new();
    for &n in fig.sizes(full) {
        let (a, b) = operand_pair(fig.workload, n, seed);
        let b_csc = csr_to_csc(&b);
        let flops = spmmm_flops(&a, &b);
        let mut vals = Vec::with_capacity(fig.series.len());
        for s in &fig.series {
            if a.rows() > s.max_feasible_n(full) {
                vals.push(None);
                continue;
            }
            let m = measure(cfg, || s.execute(&a, &b, &b_csc));
            let mflops = m.mflops(flops);
            if verbose {
                eprintln!(
                    "  fig{:02} N={:<8} {:<28} {:>10.1} MFlop/s ({} reps x {} trials)",
                    fig.id,
                    a.rows(),
                    s.label(),
                    mflops,
                    m.reps,
                    m.trials
                );
            }
            vals.push(Some(mflops));
        }
        rows.push((a.rows(), vals));
    }
    FigureResult {
        id: fig.id,
        title: fig.title.to_string(),
        series_names: fig.series.iter().map(|s| s.label()).collect(),
        rows,
    }
}

/// Entry point shared by the `rust/benches/figNN_*.rs` targets: run one
/// figure with the env-configured protocol, print table + chart, write
/// the CSV.
pub fn bench_main(figure_id: u32) {
    let fig = super::figures::figure_by_id(figure_id)
        .unwrap_or_else(|| panic!("unknown figure {figure_id}"));
    let cfg = BenchConfig::from_env();
    eprintln!(
        "blazemark figure {} [{}] — min_time={}s trials={} (BLAZEMARK_FULL=1 for paper protocol)",
        fig.id,
        fig.workload.tag(),
        cfg.min_time_s,
        cfg.trials
    );
    let res = run_figure(fig, &cfg, 0xb1a2e, true);
    println!("{}", res.render_table());
    println!("{}", res.render_chart());
    match res.write_csv() {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}

/// Schema tag of the unified structured-record format. Readers decline
/// documents carrying any other tag (same policy as the plan store:
/// version skew falls back to "no data", never to a misparse).
pub const BENCH_SCHEMA: &str = "blazert-bench-v1";

/// One row of a [`BenchRecord`]: ordered scalar fields. Fields whose
/// names the harness metric registry knows
/// ([`crate::harness::metric_orient`]) are metrics; everything else is
/// part of the row's identity key (workload, n, seed, variant axes).
pub type BenchRow = Vec<(String, Json)>;

/// The versioned structured record every bench and experiment emits —
/// one schema for `BENCH_*.json` trajectory snapshots, experiment run
/// outputs, and committed baselines, so the `compare` gate can read any
/// of them.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Emitting bench / experiment name.
    pub bench: String,
    /// The experiment's hypothesis, when one was declared.
    pub hypothesis: Option<String>,
    /// Machine-model identifier the run measured against.
    pub machine: String,
    /// Whether the emitting binary was built with `--features simd`.
    pub simd: bool,
    /// Measurement-protocol scalars (min_time_s, trials, replicates, …).
    pub config: Vec<(String, Json)>,
    /// Run-scoped extras outside the row matrix (e.g. restart counters).
    pub context: Vec<(String, Json)>,
    /// The measured matrix, one row per variant point.
    pub rows: Vec<BenchRow>,
}

/// Row field lookup by name.
pub fn row_field<'a>(row: &'a [(String, Json)], name: &str) -> Option<&'a Json> {
    row.iter().find(|(k, _)| k == name).map(|(_, v)| v)
}

impl BenchRecord {
    /// An empty record for `bench` on the default measurement machine,
    /// stamped with this build's `simd` feature state.
    pub fn new(bench: &str) -> Self {
        BenchRecord {
            bench: bench.to_string(),
            hypothesis: None,
            machine: "sandy_bridge_i7_2600".to_string(),
            simd: cfg!(feature = "simd"),
            config: Vec::new(),
            context: Vec::new(),
            rows: Vec::new(),
        }
    }

    /// The record as a JSON value (schema-tagged).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(String, Json)> =
            vec![("schema".into(), Json::Str(BENCH_SCHEMA.into()))];
        fields.push(("bench".into(), Json::Str(self.bench.clone())));
        if let Some(h) = &self.hypothesis {
            fields.push(("hypothesis".into(), Json::Str(h.clone())));
        }
        fields.push(("machine".into(), Json::Str(self.machine.clone())));
        fields.push(("simd".into(), Json::Bool(self.simd)));
        fields.push(("config".into(), Json::Obj(self.config.clone())));
        if !self.context.is_empty() {
            fields.push(("context".into(), Json::Obj(self.context.clone())));
        }
        fields.push((
            "rows".into(),
            Json::Arr(self.rows.iter().map(|r| Json::Obj(r.clone())).collect()),
        ));
        Json::Obj(fields)
    }

    /// Render the committed-snapshot JSON text.
    pub fn render(&self) -> String {
        self.to_json().render()
    }

    /// Reassemble from a parsed JSON value; declines on a missing or
    /// foreign schema tag and on malformed required fields.
    pub fn from_json(v: &Json) -> Result<BenchRecord, String> {
        let schema = v.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != BENCH_SCHEMA {
            return Err(format!("unsupported record schema '{schema}' (want {BENCH_SCHEMA})"));
        }
        let bench = v
            .get("bench")
            .and_then(Json::as_str)
            .ok_or("record missing 'bench'")?
            .to_string();
        let machine =
            v.get("machine").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let simd = v.get("simd").and_then(Json::as_bool).unwrap_or(false);
        let hypothesis = v.get("hypothesis").and_then(Json::as_str).map(str::to_string);
        let config = v.get("config").and_then(Json::as_obj).unwrap_or(&[]).to_vec();
        let context = v.get("context").and_then(Json::as_obj).unwrap_or(&[]).to_vec();
        let rows_json = v.get("rows").and_then(Json::as_arr).ok_or("record missing 'rows'")?;
        let mut rows = Vec::with_capacity(rows_json.len());
        for (i, r) in rows_json.iter().enumerate() {
            rows.push(r.as_obj().ok_or_else(|| format!("row {i} is not an object"))?.to_vec());
        }
        Ok(BenchRecord { bench, hypothesis, machine, simd, config, context, rows })
    }

    /// Parse from JSON text.
    pub fn parse(src: &str) -> Result<BenchRecord, String> {
        Self::from_json(&Json::parse(src)?)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<BenchRecord, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&src).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Write to `default_path`, honoring the `BLAZERT_BENCH_JSON`
    /// override — the one emitter every bench shares. Returns the path
    /// actually written.
    pub fn write(&self, default_path: &str) -> std::io::Result<std::path::PathBuf> {
        let path = std::env::var("BLAZERT_BENCH_JSON")
            .unwrap_or_else(|_| default_path.to_string());
        let path = std::path::PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blazemark::figures::figure_by_id;

    fn tiny_cfg() -> BenchConfig {
        BenchConfig { min_time_s: 0.0005, trials: 1 }
    }

    #[test]
    fn run_figure_2_smoke() {
        let mut fig = figure_by_id(2).unwrap().clone();
        fig.sizes_quick = vec![64, 256];
        let res = run_figure(&fig, &tiny_cfg(), 1, false);
        assert_eq!(res.rows.len(), 2);
        assert_eq!(res.series_names.len(), 3);
        for (_, vals) in &res.rows {
            for v in vals {
                assert!(v.unwrap() > 0.0);
            }
        }
        let table = res.render_table();
        assert!(table.contains("Figure 2"));
        let csv = res.to_csv();
        assert!(csv.lines().count() == 3);
    }

    #[test]
    fn caps_show_as_none() {
        let mut fig = figure_by_id(9).unwrap().clone();
        fig.sizes_quick = vec![9216]; // above the quick uBLAS cap (5000)
        let res = run_figure(&fig, &tiny_cfg(), 1, false);
        let ublas_idx = res.series_names.iter().position(|n| n.contains("uBLAS")).unwrap();
        assert!(res.rows[0].1[ublas_idx].is_none());
        let blaze_idx = res.series_names.iter().position(|n| n == "Blaze").unwrap();
        assert!(res.rows[0].1[blaze_idx].is_some());
    }

    #[test]
    fn chart_renders() {
        let mut fig = figure_by_id(6).unwrap().clone();
        fig.sizes_quick = vec![64, 144];
        let res = run_figure(&fig, &tiny_cfg(), 1, false);
        let chart = res.render_chart();
        assert!(chart.contains("MFlop/s"));
    }

    fn sample_record() -> BenchRecord {
        let mut rec = BenchRecord::new("plan_ablation");
        rec.hypothesis = Some("warm refills beat unplanned".into());
        rec.config = vec![
            ("min_time_s".into(), Json::Num(0.05)),
            ("trials".into(), Json::Num(3.0)),
        ];
        rec.context = vec![("restart_symbolic_builds".into(), Json::Num(0.0))];
        rec.rows = vec![vec![
            ("workload".into(), Json::Str("FD".into())),
            ("n".into(), Json::Num(65536.0)),
            ("plan_mode".into(), Json::Str("warm".into())),
            ("mflops".into(), Json::Num(1693.8)),
        ]];
        rec
    }

    #[test]
    fn bench_record_round_trips() {
        let rec = sample_record();
        let again = BenchRecord::parse(&rec.render()).unwrap();
        assert_eq!(rec, again);
        assert_eq!(
            row_field(&again.rows[0], "mflops").unwrap().as_f64(),
            Some(1693.8)
        );
        assert!(row_field(&again.rows[0], "missing").is_none());
    }

    #[test]
    fn bench_record_declines_foreign_schema() {
        let mut v = sample_record().to_json();
        if let Json::Obj(fields) = &mut v {
            fields[0].1 = Json::Str("blazert-bench-v999".into());
        }
        let err = BenchRecord::from_json(&v).unwrap_err();
        assert!(err.contains("unsupported record schema"), "{err}");
        assert!(BenchRecord::parse("{}").is_err(), "schema tag is mandatory");
    }
}
