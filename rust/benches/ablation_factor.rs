//! Ablation (paper §VI future work): "the decision criterion for which
//! of the two storing strategies to use might be further improved" —
//! sweep the Combined kernel's region-vs-population factor (paper: 2).

use blazert::blazemark::{measure, BenchConfig};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::spmmm::spmmm_combined_factor;
use blazert::util::table::Table;

fn main() {
    let cfg = BenchConfig::from_env();
    eprintln!("ablation: Combined decision factor; min_time={}s", cfg.min_time_s);
    let factors = [1usize, 2, 4, 8, 16, 64];
    let mut header = vec!["workload/N".to_string()];
    header.extend(factors.iter().map(|f| format!("factor {f}")));
    let mut t = Table::new(header);
    for (w, n) in [
        (Workload::FiveBandFd, 16384usize),
        (Workload::RandomFixed5, 16384),
        (Workload::RandomFill01Pct, 24000),
    ] {
        let (a, b) = operand_pair(w, n, 5);
        let flops = spmmm_flops(&a, &b);
        let mut row = vec![format!("{} N={}", w.tag(), n)];
        for &f in &factors {
            let m = measure(&cfg, || {
                std::hint::black_box(spmmm_combined_factor(&a, &b, f));
            });
            row.push(format!("{:.1}", m.mflops(flops)));
        }
        t.row(row);
    }
    println!("{}", t.render());
    println!("(MFlop/s; the paper ships factor 2)");
}
