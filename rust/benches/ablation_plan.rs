//! Ablation: the symbolic/numeric plan split on repeated spMMM.
//!
//! The repeated-traffic workloads (FD stencils re-multiplied by
//! iterative schemes, power-law service mixes) keep their sparsity
//! patterns fixed, so the structure-discovery half of every multiply is
//! redundant after the first. This bench quantifies the split four
//! ways per workload and thread count:
//!
//! * **unplanned** — the engine's regular kernel (strategy choice +
//!   structure discovery every evaluation; size-then-fill in parallel);
//! * **plan cold** — symbolic + numeric together each execution (the
//!   one-shot price of planning);
//! * **plan warm** — the plan is built once, every timed execution is a
//!   pure numeric refill (the steady-state path a plan-cache hit takes);
//! * **disk-warm** — a *fresh* session (simulated restart) recovers the
//!   plan from the on-disk store and refills numerically — the
//!   "restart without re-warming" path; its session must report zero
//!   symbolic builds.
//!
//! Warm/unplanned > 1 is the payoff of caching the symbolic phase;
//! warm/cold is the share of an evaluation the structure discovery was;
//! disk-warm ≈ warm shows persistence costs nothing at steady state.

use std::sync::Arc;

use blazert::blazemark::{BenchConfig, PlanMode, SweepSession};
use blazert::exec::Partition;
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::Strategy;
use blazert::plan::PlanStore;
use blazert::util::table::Table;

fn main() {
    let cfg = BenchConfig::from_env();
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let max_threads = cores.min(8).max(1);
    eprintln!(
        "ablation: plan split (cold vs warm vs disk-warm) on {cores} cores; min_time={}s",
        cfg.min_time_s
    );
    let store_dir =
        std::env::temp_dir().join(format!("blazert_ablation_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = Arc::new(PlanStore::open_default(&store_dir).expect("plan store opens"));
    let mut session = SweepSession::new(max_threads);
    let mut threads = vec![1usize];
    if max_threads > 1 {
        threads.push(max_threads);
    }

    let mut restart_symbolic_builds = 0u64;
    let mut t = Table::new([
        "workload/N",
        "thr",
        "unplanned MF/s",
        "cold MF/s",
        "warm MF/s",
        "disk MF/s",
        "warm/unplanned",
    ]);
    for (w, n) in [(Workload::FiveBandFd, 65536usize), (Workload::PowerLawSkew, 32768)] {
        let (a, b) = operand_pair(w, n, 5);
        let flops = spmmm_flops(&a, &b);
        for &thr in &threads {
            let unplanned = session
                .measure_spmmm(&cfg, &a, &b, Strategy::Combined, thr, Partition::Flops)
                .mflops(flops);
            let cold = session
                .measure_spmmm_planned(&cfg, &a, &b, thr, Partition::Flops, PlanMode::Cold)
                .mflops(flops);
            let warm = session
                .measure_spmmm_planned(&cfg, &a, &b, thr, Partition::Flops, PlanMode::Warm)
                .mflops(flops);
            // Persist the long-lived session's plans, then measure a
            // fresh session (the simulated restart) that warm-starts
            // from the store directory.
            session.persist_plans(&store);
            let mut restarted = SweepSession::new(max_threads);
            restarted.attach_plan_store(&store);
            let disk = restarted
                .measure_spmmm_planned(&cfg, &a, &b, thr, Partition::Flops, PlanMode::Persisted)
                .mflops(flops);
            restart_symbolic_builds += restarted.plan_stats().symbolic_builds;
            t.row([
                format!("{} N={}", w.tag(), n),
                format!("{thr}"),
                format!("{unplanned:.0}"),
                format!("{cold:.0}"),
                format!("{warm:.0}"),
                format!("{disk:.0}"),
                format!("{:.2}x", warm / unplanned.max(1e-9)),
            ]);
        }
    }
    println!("{}", t.render());
    let s = session.plan_stats();
    eprintln!(
        "plan cache: {} hits, {} misses, {} symbolic builds, {} evictions",
        s.hits, s.misses, s.symbolic_builds, s.evictions
    );
    let ss = store.stats();
    eprintln!(
        "plan store: {} saved, {} loaded, {} rejected, {} evicted \
         ({} bytes on disk); restarted sessions ran {} symbolic builds (want 0)",
        ss.saved,
        ss.loaded,
        ss.store_rejected,
        ss.evicted,
        store.total_bytes(),
        restart_symbolic_builds,
    );
    let _ = std::fs::remove_dir_all(&store_dir);
}
