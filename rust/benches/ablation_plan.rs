//! Ablation: symbolic/numeric plan split — thin wrapper over the
//! committed definition `experiments/plan_ablation.toml`.
//!
//! The matrix (unplanned / cold / warm / disk-warm × threads, on the FD
//! and power-law workloads), the measurement protocol, and the noise
//! bands all live in the definition; this target only selects the tier
//! (`BLAZEMARK_FULL=1` for the paper protocol) and the default output
//! path. `BLAZERT_BENCH_JSON` overrides where the record lands. The
//! same definition drives `cargo run --bin experiment -- run|compare`,
//! which is what CI gates on.

fn main() {
    blazert::harness::bench_main("experiments/plan_ablation.toml", "BENCH_plan.json");
}
