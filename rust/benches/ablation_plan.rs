//! Ablation: the symbolic/numeric plan split on repeated spMMM.
//!
//! The repeated-traffic workloads (FD stencils re-multiplied by
//! iterative schemes, power-law service mixes) keep their sparsity
//! patterns fixed, so the structure-discovery half of every multiply is
//! redundant after the first. This bench quantifies the split four
//! ways per workload and thread count:
//!
//! * **unplanned** — the engine's regular kernel (strategy choice +
//!   structure discovery every evaluation; size-then-fill in parallel);
//! * **plan cold** — symbolic + numeric together each execution (the
//!   one-shot price of planning);
//! * **plan warm** — the plan is built once, every timed execution is a
//!   pure numeric refill (the steady-state path a plan-cache hit takes);
//! * **disk-warm** — a *fresh* session (simulated restart) recovers the
//!   plan from the on-disk store and refills numerically — the
//!   "restart without re-warming" path; its session must report zero
//!   symbolic builds.
//!
//! Warm/unplanned > 1 is the payoff of caching the symbolic phase;
//! warm/cold is the share of an evaluation the structure discovery was;
//! disk-warm ≈ warm shows persistence costs nothing at steady state.
//! The `warm %roof` column validates the warm refill against the
//! model: measured time vs the roofline transfer time of the refill's
//! byte lower bound (`planned_fill_lower_bound_bytes`).
//!
//! Results are also emitted as structured JSON (default
//! `BENCH_plan.json` in the working directory; override the path with
//! `BLAZERT_BENCH_JSON`).

use std::sync::Arc;

use blazert::blazemark::{BenchConfig, Measurement, PlanMode, SweepSession};
use blazert::exec::Partition;
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::Strategy;
use blazert::model::planned_fill_lower_bound_bytes;
use blazert::plan::PlanStore;
use blazert::sparse::SparseShape;
use blazert::util::table::Table;

struct Row {
    workload: &'static str,
    n: usize,
    threads: usize,
    unplanned: Measurement,
    cold: Measurement,
    warm: Measurement,
    disk: Measurement,
    flops: u64,
    warm_bytes: u64,
    warm_roofline_pct: f64,
}

fn main() {
    let cfg = BenchConfig::from_env();
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let max_threads = cores.min(8).max(1);
    eprintln!(
        "ablation: plan split (cold vs warm vs disk-warm) on {cores} cores; min_time={}s",
        cfg.min_time_s
    );
    let store_dir =
        std::env::temp_dir().join(format!("blazert_ablation_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = Arc::new(PlanStore::open_default(&store_dir).expect("plan store opens"));
    let mut session = SweepSession::new(max_threads);
    let mut threads = vec![1usize];
    if max_threads > 1 {
        threads.push(max_threads);
    }

    let mut rows: Vec<Row> = Vec::new();
    let mut restart_symbolic_builds = 0u64;
    for (w, n) in [(Workload::FiveBandFd, 65536usize), (Workload::PowerLawSkew, 32768)] {
        let (a, b) = operand_pair(w, n, 5);
        let flops = spmmm_flops(&a, &b);
        for &thr in &threads {
            let unplanned =
                session.measure_spmmm(&cfg, &a, &b, Strategy::Combined, thr, Partition::Flops);
            let cold =
                session.measure_spmmm_planned(&cfg, &a, &b, thr, Partition::Flops, PlanMode::Cold);
            let warm =
                session.measure_spmmm_planned(&cfg, &a, &b, thr, Partition::Flops, PlanMode::Warm);
            // The filled output's population is a (slight) lower bound
            // on the plan's pattern, so the derived traffic floor stays
            // a true floor and the percentage stays honest.
            let warm_bytes =
                planned_fill_lower_bound_bytes(a.nnz(), b.nnz(), session.out().nnz());
            let warm_roofline_pct =
                session.roofline_percent(flops as f64, warm_bytes as f64, &warm);
            // Persist the long-lived session's plans, then measure a
            // fresh session (the simulated restart) that warm-starts
            // from the store directory.
            session.persist_plans(&store);
            let mut restarted = SweepSession::new(max_threads);
            restarted.attach_plan_store(&store);
            let disk = restarted
                .measure_spmmm_planned(&cfg, &a, &b, thr, Partition::Flops, PlanMode::Persisted);
            restart_symbolic_builds += restarted.plan_stats().symbolic_builds;
            rows.push(Row {
                workload: w.tag(),
                n,
                threads: thr,
                unplanned,
                cold,
                warm,
                disk,
                flops,
                warm_bytes,
                warm_roofline_pct,
            });
        }
    }

    let mut t = Table::new([
        "workload/N",
        "thr",
        "unplanned MF/s",
        "cold MF/s",
        "warm MF/s",
        "disk MF/s",
        "warm/unplanned",
        "warm %roof",
    ]);
    for r in &rows {
        let unplanned = r.unplanned.mflops(r.flops);
        let warm = r.warm.mflops(r.flops);
        t.row([
            format!("{} N={}", r.workload, r.n),
            format!("{}", r.threads),
            format!("{unplanned:.0}"),
            format!("{:.0}", r.cold.mflops(r.flops)),
            format!("{warm:.0}"),
            format!("{:.0}", r.disk.mflops(r.flops)),
            format!("{:.2}x", warm / unplanned.max(1e-9)),
            format!("{:.0}%", r.warm_roofline_pct),
        ]);
    }
    println!("{}", t.render());
    let s = session.plan_stats();
    eprintln!(
        "plan cache: {} hits, {} misses, {} symbolic builds, {} evictions",
        s.hits, s.misses, s.symbolic_builds, s.evictions
    );
    let ss = store.stats();
    eprintln!(
        "plan store: {} saved, {} loaded, {} rejected, {} evicted \
         ({} bytes on disk); restarted sessions ran {} symbolic builds (want 0)",
        ss.saved,
        ss.loaded,
        ss.store_rejected,
        ss.evicted,
        store.total_bytes(),
        restart_symbolic_builds,
    );

    let json_path =
        std::env::var("BLAZERT_BENCH_JSON").unwrap_or_else(|_| "BENCH_plan.json".to_string());
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"ablation_plan\",\n");
    json.push_str("  \"machine\": \"sandy_bridge_i7_2600\",\n");
    json.push_str(&format!("  \"simd\": {},\n", cfg!(feature = "simd")));
    json.push_str(&format!(
        "  \"config\": {{ \"min_time_s\": {}, \"trials\": {} }},\n",
        cfg.min_time_s, cfg.trials
    ));
    json.push_str(&format!(
        "  \"restart_symbolic_builds\": {restart_symbolic_builds},\n"
    ));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{ \"workload\": \"{}\", \"n\": {}, \"threads\": {}, \
             \"flops\": {}, \"unplanned_mflops\": {:.1}, \"cold_mflops\": {:.1}, \
             \"warm_mflops\": {:.1}, \"disk_mflops\": {:.1}, \
             \"warm_bytes_floor\": {}, \"warm_roofline_pct\": {:.1} }}{}\n",
            r.workload,
            r.n,
            r.threads,
            r.flops,
            r.unplanned.mflops(r.flops),
            r.cold.mflops(r.flops),
            r.warm.mflops(r.flops),
            r.disk.mflops(r.flops),
            r.warm_bytes,
            r.warm_roofline_pct,
            if i + 1 == rows.len() { "" } else { "," },
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&json_path, &json) {
        Ok(()) => eprintln!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&store_dir);
}
