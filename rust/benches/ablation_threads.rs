//! Ablation (paper §VI future work): shared-memory parallel spMMM
//! scaling — "we expect that the typical contention and saturation
//! effects seen with these architectures will add many new effects".

use blazert::blazemark::{measure, BenchConfig};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::parallel::par_spmmm;
use blazert::util::table::Table;

fn main() {
    let cfg = BenchConfig::from_env();
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    eprintln!("ablation: parallel spMMM scaling on {cores} cores; min_time={}s", cfg.min_time_s);
    let threads: Vec<usize> =
        [1usize, 2, 4, 8, 16].into_iter().filter(|&t| t <= 2 * cores).collect();
    let mut header = vec!["workload/N".to_string()];
    header.extend(threads.iter().map(|t| format!("{t} thr")));
    header.push("speedup@max".into());
    let mut t = Table::new(header);
    for (w, n) in [(Workload::FiveBandFd, 262144usize), (Workload::RandomFixed5, 65536)] {
        let (a, b) = operand_pair(w, n, 5);
        let flops = spmmm_flops(&a, &b);
        let mut row = vec![format!("{} N={}", w.tag(), n)];
        let mut first = 0.0f64;
        let mut last = 0.0f64;
        for &thr in &threads {
            let m = measure(&cfg, || {
                std::hint::black_box(par_spmmm(&a, &b, thr));
            });
            let mf = m.mflops(flops);
            if thr == 1 {
                first = mf;
            }
            last = mf;
            row.push(format!("{mf:.0}"));
        }
        row.push(format!("{:.2}x", last / first.max(1e-9)));
        t.row(row);
    }
    println!("{}", t.render());
}
