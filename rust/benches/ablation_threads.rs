//! Ablation (paper §VI future work): shared-memory parallel spMMM
//! scaling — "we expect that the typical contention and saturation
//! effects seen with these architectures will add many new effects" —
//! now measured through the persistent execution engine (one pool +
//! workspaces reused across the whole sweep), plus a partitioning
//! ablation: row-balanced vs flop-balanced vs model-guided slabs on a
//! skewed power-law workload, where equal row counts serialize on the
//! hottest slab.

use blazert::blazemark::{BenchConfig, SweepSession};
use blazert::exec::Partition;
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::Strategy;
use blazert::util::table::Table;

fn main() {
    let cfg = BenchConfig::from_env();
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    eprintln!("ablation: parallel spMMM scaling on {cores} cores; min_time={}s", cfg.min_time_s);
    let threads: Vec<usize> =
        [1usize, 2, 4, 8, 16].into_iter().filter(|&t| t <= 2 * cores).collect();
    let mut session = SweepSession::new(*threads.last().unwrap_or(&1));

    // Part 1: thread scaling (flop-balanced, the engine default).
    let mut header = vec!["workload/N".to_string()];
    header.extend(threads.iter().map(|t| format!("{t} thr")));
    header.push("speedup@max".into());
    let mut t = Table::new(header);
    for (w, n) in [(Workload::FiveBandFd, 262144usize), (Workload::RandomFixed5, 65536)] {
        let (a, b) = operand_pair(w, n, 5);
        let flops = spmmm_flops(&a, &b);
        let mut row = vec![format!("{} N={}", w.tag(), n)];
        let mut first = 0.0f64;
        let mut last = 0.0f64;
        for &thr in &threads {
            let m = session.measure_spmmm(
                &cfg,
                &a,
                &b,
                Strategy::Combined,
                thr,
                Partition::Flops,
            );
            let mf = m.mflops(flops);
            if thr == 1 {
                first = mf;
            }
            last = mf;
            row.push(format!("{mf:.0}"));
        }
        row.push(format!("{:.2}x", last / first.max(1e-9)));
        t.row(row);
    }
    println!("{}", t.render());

    // Part 2: partitioning ablation on the skewed power-law workload.
    // Row-balanced slabs serialize on the hot rows; flop-balanced and
    // model-guided slabs split by predicted work.
    let n = 65536usize;
    let (a, b) = operand_pair(Workload::PowerLawSkew, n, 5);
    let flops = spmmm_flops(&a, &b);
    eprintln!("partition ablation: {} N={n}, {} flops", Workload::PowerLawSkew.tag(), flops);
    let mut header = vec!["partition".to_string()];
    header.extend(threads.iter().map(|t| format!("{t} thr")));
    let mut t = Table::new(header);
    for part in Partition::ALL {
        let mut row = vec![part.name().to_string()];
        for &thr in &threads {
            let m = session.measure_spmmm(&cfg, &a, &b, Strategy::Combined, thr, part);
            row.push(format!("{:.0}", m.mflops(flops)));
        }
        t.row(row);
    }
    println!("{}", t.render());
}
