//! Ablation (paper §VI future work): shared-memory parallel spMMM
//! scaling × slab partitioning — thin wrapper over the committed
//! definition `experiments/threads_ablation.toml`.
//!
//! Row-balanced vs flop-balanced vs model-guided slabs at 1..8 threads
//! on an even (FD) and a skewed (power-law) workload, where equal row
//! counts serialize on the hottest slab. `BLAZEMARK_FULL=1` selects the
//! paper protocol; `BLAZERT_BENCH_JSON` overrides the output path.

fn main() {
    blazert::harness::bench_main("experiments/threads_ablation.toml", "BENCH_threads.json");
}
