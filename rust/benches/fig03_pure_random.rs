//! Regenerates Figure 3 of the paper (see DESIGN.md §6).
//! Protocol: Blazemark quick sweep by default; BLAZEMARK_FULL=1 for the
//! paper's 2 s / best-of-5 protocol and paper-scale problem sizes.
fn main() {
    blazert::blazemark::report::bench_main(3);
}
