//! Ablation: lane-unrolled planned numeric phase — thin wrapper over
//! the committed definition `experiments/simd_ablation.toml`.
//!
//! One binary is compiled either with or without the `simd` feature and
//! records `"simd": true/false` in its output — run it twice,
//!
//! ```text
//! cargo bench --bench ablation_simd
//! cargo bench --bench ablation_simd --features simd
//! ```
//!
//! and compare the two `BENCH_simd.json` files (override the output
//! path with `BLAZERT_BENCH_JSON` to keep both). The warm CSR rows
//! cover the serial and parallel planned refills, the CSC rows the
//! column-major streaming fill; both builds produce bit-identical
//! results (`tests/integration_exec.rs` pins that).

fn main() {
    blazert::harness::bench_main("experiments/simd_ablation.toml", "BENCH_simd.json");
}
