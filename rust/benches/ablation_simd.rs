//! Ablation: the lane-unrolled planned numeric phase (`--features simd`).
//!
//! One binary is compiled either with or without the `simd` feature, so
//! this bench measures whichever numeric phase it was built with and
//! records `"simd": true/false` in its output — run it twice,
//!
//! ```text
//! cargo bench --bench ablation_simd
//! cargo bench --bench ablation_simd --features simd
//! ```
//!
//! and compare the two `BENCH_simd.json` files (override the output
//! path with `BLAZERT_BENCH_JSON`, e.g. to keep both). The kernels are
//! the tentpole's vectorization targets, all measured warm (plan built
//! once, timed region pure numeric refill):
//!
//! * **serial** — `planned_fill_serial`, one thread;
//! * **parallel** — `par_planned_fill` over the pool's column slabs;
//! * **csc** — `planned_fill_serial_csc`, the column-major streaming
//!   fill.
//!
//! Per kernel the table reports MFlop/s and percent-of-roofline: the
//! model's transfer time for the refill's byte floor
//! (`planned_fill_lower_bound_bytes`) over the measured time. Both
//! builds produce bit-identical results (`tests/integration_exec.rs`
//! pins that); the percentage is where the unrolled lanes and the
//! software prefetch should show up.

use blazert::blazemark::{BenchConfig, Measurement, PlanMode, SweepSession};
use blazert::exec::Partition;
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::model::planned_fill_lower_bound_bytes;
use blazert::sparse::convert::csr_to_csc;
use blazert::sparse::SparseShape;
use blazert::util::table::Table;

struct Row {
    workload: &'static str,
    n: usize,
    kernel: &'static str,
    threads: usize,
    flops: u64,
    bytes_floor: u64,
    m: Measurement,
    roofline_pct: f64,
}

fn main() {
    let cfg = BenchConfig::from_env();
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
    let max_threads = cores.min(8).max(1);
    let simd = cfg!(feature = "simd");
    eprintln!(
        "ablation: planned numeric phase, simd={simd} on {cores} cores; min_time={}s",
        cfg.min_time_s
    );

    let mut session = SweepSession::new(max_threads);
    let mut rows: Vec<Row> = Vec::new();
    for (w, n) in [(Workload::FiveBandFd, 65536usize), (Workload::PowerLawSkew, 32768)] {
        let (a, b) = operand_pair(w, n, 5);
        let flops = spmmm_flops(&a, &b);
        let mut push = |kernel, threads, m: Measurement, out_nnz: usize, session: &SweepSession| {
            let bytes_floor = planned_fill_lower_bound_bytes(a.nnz(), b.nnz(), out_nnz);
            let roofline_pct = session.roofline_percent(flops as f64, bytes_floor as f64, &m);
            rows.push(Row {
                workload: w.tag(),
                n,
                kernel,
                threads,
                flops,
                bytes_floor,
                m,
                roofline_pct,
            });
        };
        let m = session.measure_spmmm_planned(&cfg, &a, &b, 1, Partition::Flops, PlanMode::Warm);
        push("serial", 1, m, session.out().nnz(), &session);
        if max_threads > 1 {
            let m = session.measure_spmmm_planned(
                &cfg,
                &a,
                &b,
                max_threads,
                Partition::Flops,
                PlanMode::Warm,
            );
            push("parallel", max_threads, m, session.out().nnz(), &session);
        }
        let (ac, bc) = (csr_to_csc(&a), csr_to_csc(&b));
        let m =
            session.measure_spmmm_csc_planned(&cfg, &ac, &bc, 1, Partition::Flops, PlanMode::Warm);
        push("csc", 1, m, session.out_csc().nnz(), &session);
    }

    let mut t = Table::new(["workload/N", "kernel", "thr", "MF/s", "%roofline"]);
    for r in &rows {
        t.row([
            format!("{} N={}", r.workload, r.n),
            r.kernel.to_string(),
            format!("{}", r.threads),
            format!("{:.0}", r.m.mflops(r.flops)),
            format!("{:.0}%", r.roofline_pct),
        ]);
    }
    println!("{}", t.render());
    let s = session.plan_stats();
    eprintln!(
        "plan cache: {} hits, {} symbolic builds (one per kernel shape)",
        s.hits, s.symbolic_builds
    );

    let json_path =
        std::env::var("BLAZERT_BENCH_JSON").unwrap_or_else(|_| "BENCH_simd.json".to_string());
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"ablation_simd\",\n");
    json.push_str("  \"machine\": \"sandy_bridge_i7_2600\",\n");
    json.push_str(&format!("  \"simd\": {simd},\n"));
    json.push_str(&format!(
        "  \"config\": {{ \"min_time_s\": {}, \"trials\": {} }},\n",
        cfg.min_time_s, cfg.trials
    ));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{ \"workload\": \"{}\", \"n\": {}, \"kernel\": \"{}\", \"threads\": {}, \
             \"flops\": {}, \"mflops\": {:.1}, \"bytes_floor\": {}, \
             \"roofline_pct\": {:.1} }}{}\n",
            r.workload,
            r.n,
            r.kernel,
            r.threads,
            r.flops,
            r.m.mflops(r.flops),
            r.bytes_floor,
            r.roofline_pct,
            if i + 1 == rows.len() { "" } else { "," },
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&json_path, &json) {
        Ok(()) => eprintln!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
