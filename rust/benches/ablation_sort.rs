//! Ablation (paper §VI future work): comparison sort vs LSD radix sort
//! for the Sort storing strategy's short index lists, across row
//! populations (controlled via the fill-ratio generator).

use blazert::blazemark::{measure, BenchConfig};
use blazert::gen::random_fill_ratio;
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::{spmmm, Strategy};
use blazert::util::table::Table;

fn main() {
    let cfg = BenchConfig::from_env();
    eprintln!("ablation: Sort (comparison) vs Sort-radix; min_time={}s", cfg.min_time_s);
    let mut t = Table::new(["N", "nnz/row", "Sort MF/s", "Sort-radix MF/s", "radix gain"]);
    // Sweep row population: few entries (insertion-sort regime) to many
    // (radix-counting regime).
    for (n, fill) in [
        (20_000usize, 0.0005f64),
        (20_000, 0.002),
        (10_000, 0.01),
        (4_000, 0.05),
        (2_000, 0.1),
    ] {
        let a = random_fill_ratio(n, n, fill, 1);
        let b = random_fill_ratio(n, n, fill, 2);
        let flops = spmmm_flops(&a, &b);
        let m_sort = measure(&cfg, || {
            std::hint::black_box(spmmm(&a, &b, Strategy::Sort));
        });
        let m_radix = measure(&cfg, || {
            std::hint::black_box(spmmm(&a, &b, Strategy::SortRadix));
        });
        let (s, r) = (m_sort.mflops(flops), m_radix.mflops(flops));
        t.row([
            n.to_string(),
            format!("{:.0}", fill * n as f64),
            format!("{s:.1}"),
            format!("{r:.1}"),
            format!("{:+.1}%", 100.0 * (r / s - 1.0)),
        ]);
    }
    println!("{}", t.render());
}
