//! Multi-tenant service contracts, end to end: crash recovery through
//! expiring leases (a dead worker's job is reclaimed, re-executed
//! exactly once, and the result is bit-identical to an undisturbed
//! run), tenant-fair weighted scheduling with no starvation, per-tenant
//! FIFO, admission-control backpressure that recovers after a drain,
//! and per-tenant plan-store byte quotas whose eviction never crosses
//! tenant directories.

use std::sync::Arc;

use blazert::exec::{default_machine, ExecPool, Partition};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::{spmmm, Strategy};
use blazert::runtime::tenant_state_dir;
use blazert::service::{JobService, PlanQuotas, ServiceConfig, SubmitError};
use blazert::sparse::CsrMatrix;

fn service(lease_ns: u64, max_attempts: u32) -> JobService<u32> {
    JobService::new(ServiceConfig { lease_timeout_ns: lease_ns, max_attempts })
}

fn bits(m: &CsrMatrix) -> (Vec<usize>, Vec<usize>, Vec<u64>) {
    (
        m.row_ptr().to_vec(),
        m.col_idx().to_vec(),
        m.values().iter().map(|v| v.to_bits()).collect(),
    )
}

#[test]
fn dead_worker_job_is_reclaimed_and_reexecuted_exactly_once() {
    let (a, b) = operand_pair(Workload::RandomFixed5, 120, 5);
    let undisturbed = spmmm(&a, &b, Strategy::Combined);

    let svc = service(1_000, 3);
    let tenant = svc.register_tenant("acme", 1, 4);
    svc.submit(tenant, 0).unwrap();

    // Worker A claims the job and dies mid-execution: no complete ever
    // arrives, the lease just expires.
    let doomed = svc.claim().unwrap();
    assert_eq!(doomed.attempt, 1);
    svc.advance(1_000_000);

    // Worker B's claim reaps the expired lease and is offered the very
    // same job, second attempt.
    let retry = svc.claim().unwrap();
    assert_eq!((retry.job, retry.attempt, retry.tenant), (0, 2, tenant));
    let recovered = spmmm(&a, &b, Strategy::Combined);
    assert!(svc.complete(retry.token).is_some(), "live lease completes");

    // The dead worker's ghost result is fenced off as a duplicate...
    assert!(svc.complete(doomed.token).is_none(), "stale lease is fenced");
    // ...so the job completed exactly once, nothing was lost, and the
    // recovered result is bit-identical to the undisturbed run.
    let c = svc.counters();
    assert_eq!((c.completed, c.requeued, c.lost, c.stale_results), (1, 1, 0, 1));
    assert_eq!(svc.pending(), 0);
    assert_eq!(bits(&recovered), bits(&undisturbed));
}

#[test]
fn per_tenant_jobs_complete_in_submission_order() {
    let svc = service(u64::MAX / 2, 3);
    let tenant = svc.register_tenant("ordered", 1, 8);
    for j in 0..8u32 {
        svc.submit(tenant, j).unwrap();
    }
    let mut seen = Vec::new();
    while let Some(claim) = svc.claim() {
        seen.push(claim.job);
        svc.complete(claim.token);
    }
    assert_eq!(seen, (0..8).collect::<Vec<_>>(), "single tenant drains FIFO");
}

#[test]
fn weighted_round_robin_interleaves_three_to_one() {
    let svc = service(u64::MAX / 2, 3);
    let heavy = svc.register_tenant("heavy", 3, 16);
    let light = svc.register_tenant("light", 1, 16);
    for j in 0..8u32 {
        svc.submit(heavy, j).unwrap();
        svc.submit(light, j).unwrap();
    }
    let order: Vec<usize> = (0..8).map(|_| svc.claim().unwrap().tenant.index()).collect();
    // Smooth WRR at weights 3:1 cycles [heavy, heavy, light, heavy] —
    // the light tenant is served inside every window, never bunched at
    // the end.
    let (h, l) = (heavy.index(), light.index());
    assert_eq!(order, vec![h, h, l, h, h, h, l, h]);
}

#[test]
fn no_tenant_starves_under_a_dominant_neighbour() {
    let svc = service(u64::MAX / 2, 3);
    let weights = [10u64, 1, 1, 1];
    let tenants: Vec<_> = weights
        .iter()
        .enumerate()
        .map(|(i, &w)| svc.register_tenant(&format!("t{i}"), w, 32))
        .collect();
    for t in &tenants {
        for j in 0..32u32 {
            svc.submit(*t, j).unwrap();
        }
    }
    // Over one full weight cycle (Σw = 13 picks) every tenant is served
    // exactly its weight — the light tenants are never starved out by
    // the 10x neighbour.
    let mut per_cycle = [0usize; 4];
    for _ in 0..13 {
        per_cycle[svc.claim().unwrap().tenant.index()] += 1;
    }
    assert_eq!(per_cycle, [10, 1, 1, 1]);
    let mut second = [0usize; 4];
    for _ in 0..13 {
        second[svc.claim().unwrap().tenant.index()] += 1;
    }
    assert_eq!(second, [10, 1, 1, 1], "the share repeats cycle after cycle");
}

#[test]
fn admission_control_rejects_when_full_and_recovers_after_drain() {
    let svc = service(u64::MAX / 2, 3);
    let tenant = svc.register_tenant("bursty", 1, 2);
    svc.submit(tenant, 1).unwrap();
    svc.submit(tenant, 2).unwrap();
    let err = svc.submit(tenant, 3).unwrap_err();
    assert_eq!(err, SubmitError::QueueFull { tenant: "bursty".into(), depth: 2 });
    // Draining one job frees a slot; admission recovers immediately.
    let claim = svc.claim().unwrap();
    svc.complete(claim.token);
    svc.submit(tenant, 3).unwrap();
    let c = svc.counters();
    assert_eq!((c.submitted, c.rejected), (3, 1));
}

#[test]
fn reclaimed_jobs_jump_the_queue_and_ignore_the_depth_bound() {
    let svc = service(1_000, 3);
    let tenant = svc.register_tenant("narrow", 1, 1);
    svc.submit(tenant, 1).unwrap();
    let doomed = svc.claim().unwrap();
    // The queue slot freed by the claim admits a second job...
    svc.submit(tenant, 2).unwrap();
    assert!(svc.submit(tenant, 3).is_err(), "depth 1 is full again");
    // ...then the lease expires. The reaped job re-enters at the FRONT
    // of the (already full) queue: requeues are exempt from the depth
    // bound and abandoned work is retried before newer work.
    svc.advance(1_000_000);
    let first = svc.claim().unwrap();
    assert_eq!((first.job, first.attempt), (1, 2));
    let second = svc.claim().unwrap();
    assert_eq!((second.job, second.attempt), (2, 1));
    svc.complete(first.token);
    svc.complete(second.token);
    assert!(svc.complete(doomed.token).is_none(), "dead worker's result is dropped");
    let c = svc.counters();
    assert_eq!((c.completed, c.requeued, c.lost), (2, 1, 0));
}

#[test]
fn plan_quotas_isolate_tenant_stores() {
    let dir =
        std::env::temp_dir().join(format!("blazert_tenant_quota_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let pool = ExecPool::new(1);
    let (fa, fb) = operand_pair(Workload::FiveBandFd, 300, 11);

    {
        let quotas = PlanQuotas::open(&dir, 1 << 20);
        // `alpha` opens with a 1-byte override: every write-through
        // blows the budget and is immediately evicted. `beta` keeps the
        // registry default.
        let alpha = quotas.tenant("alpha", Some(1)).expect("alpha opens");
        let beta = quotas.tenant("beta", None).expect("beta opens");
        assert_eq!((alpha.quota_bytes, beta.quota_bytes), (1, 1 << 20));
        pool.with_local(|ws| {
            alpha.cache.get_or_build(default_machine(), ws, &fa, &fb, 1, Partition::Flops);
            beta.cache.get_or_build(default_machine(), ws, &fa, &fb, 1, Partition::Flops);
        });
        // Same plan, two fates: beta's store keeps it, alpha's byte
        // quota evicted it — and only alpha's directory was touched by
        // that eviction.
        assert_eq!(beta.warm.store.len(), 1, "beta persists under its budget");
        assert_eq!(alpha.warm.store.len(), 0, "alpha's quota evicts its own plan");
        // The stores live in disjoint per-tenant directories.
        assert_eq!(alpha.warm.store.dir(), tenant_state_dir(&dir, "alpha"));
        assert_eq!(beta.warm.store.dir(), tenant_state_dir(&dir, "beta"));
        // Re-fetching a tenant returns the already-open state, original
        // budget intact.
        let beta_again = quotas.tenant("beta", Some(7)).expect("cached handle");
        assert!(Arc::ptr_eq(&beta, &beta_again));
        assert_eq!(beta_again.quota_bytes, 1 << 20);
        assert_eq!(quotas.len(), 2);
    }

    // Simulated restart: a fresh registry over the same state dir
    // warm-starts beta from its surviving plan; alpha starts cold.
    let reopened = PlanQuotas::open(&dir, 1 << 20);
    let beta = reopened.tenant("beta", None).expect("beta reopens");
    let alpha = reopened.tenant("alpha", None).expect("alpha reopens");
    assert_eq!(beta.warm.plans_loaded, 1, "restart recovers beta's plan");
    assert_eq!(alpha.warm.plans_loaded, 0, "alpha has nothing to recover");

    // Tenant names are sanitized into path-safe directories.
    let weird = reopened.tenant("we/ird name", None).expect("sanitized open");
    assert_eq!(weird.warm.store.dir(), tenant_state_dir(&dir, "we/ird name"));
    assert_eq!(
        tenant_state_dir(&dir, "we/ird name"),
        dir.join("tenant_we_ird_name"),
        "path separators and spaces are mapped to underscores"
    );
    std::fs::remove_dir_all(&dir).ok();
}
