//! Fused spMMM→SpMV pipeline, end to end: the fused evaluation must be
//! **bit-identical** to materializing the sparse product and then
//! multiplying by the vector — across every storing strategy, partition
//! scheme, and thread count, through both `EvalContext::fused_matvec`
//! and the expression layer (`(&a * &b * &x).eval()`, the `+ y` tail,
//! and the `with_fanout` materialized fallback), and including the
//! floating-point edge cases where "close" is not "equal": exact
//! cancellation in the intermediate, empty rows, and NaN payloads.
//! The same contract extends to streamed ≥3-factor chains
//! (`EvalContext::streamed_matvec`, `(&a * &b * &c * &x)`): every
//! lowering must reproduce the materialize-every-hop loop bit for bit.
//! Because every check compares fused bits against materialized bits
//! (never against a hand-computed oracle), the file passes unchanged
//! with and without `--features simd`.

use std::borrow::Cow;

use blazert::exec::{default_machine, ExecPool, Partition};
use blazert::expr::{EvalContext, Expression};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::spmv::spmv;
use blazert::kernels::{spmmm, Strategy};
use blazert::plan::PlanCache;
use blazert::sparse::{CsrMatrix, SparseShape};

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Materialized reference: C = A·B stored, then y = C·x (+ tail).
fn materialized(
    a: &CsrMatrix,
    b: &CsrMatrix,
    x: &[f64],
    tail: Option<&[f64]>,
    strategy: Strategy,
) -> Vec<f64> {
    let c = spmmm(a, b, strategy);
    let mut y = vec![0.0; a.rows()];
    spmv(&c, x, &mut y);
    if let Some(t) = tail {
        for (yr, tv) in y.iter_mut().zip(t) {
            *yr += *tv;
        }
    }
    y
}

fn probe_vector(n: usize) -> Vec<f64> {
    (0..n).map(|i| 0.25 + (i % 7) as f64 * 0.5 - (i % 3) as f64).collect()
}

/// Materialized chain reference: every hop stored, then y = (…)·x.
fn materialized_chain(factors: &[&CsrMatrix], x: &[f64], strategy: Strategy) -> Vec<f64> {
    let mut m = spmmm(factors[0], factors[1], strategy);
    for f in &factors[2..] {
        m = spmmm(&m, f, strategy);
    }
    let mut y = vec![0.0; m.rows()];
    spmv(&m, x, &mut y);
    y
}

#[test]
fn fused_matches_materialized_across_strategies_partitions_threads() {
    let pool = ExecPool::new(3);
    for w in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::PowerLawSkew] {
        let (a, b) = operand_pair(w, 180, 7);
        let x = probe_vector(b.cols());
        for s in Strategy::ALL {
            let want = materialized(&a, &b, &x, None, s);
            for threads in [1usize, 2, 5] {
                for partition in [Partition::Rows, Partition::Flops, Partition::Model] {
                    let mut ctx = EvalContext::using(s)
                        .with_exec(&pool)
                        .with_threads(threads)
                        .with_partition(partition)
                        .with_machine(default_machine());
                    let mut y = vec![0.0; a.rows()];
                    ctx.fused_matvec(&a, &b, &x, &mut y);
                    assert_eq!(
                        bits(&y),
                        bits(&want),
                        "{w:?} {} threads={threads} {partition:?}",
                        s.name()
                    );
                }
            }
        }
    }
}

#[test]
fn expression_layer_lowers_to_the_same_bits() {
    let pool = ExecPool::new(2);
    let (a, b) = operand_pair(Workload::RandomFixed5, 160, 21);
    let x = probe_vector(b.cols());
    let tail: Vec<f64> = (0..a.rows()).map(|i| i as f64 * 0.125 - 4.0).collect();
    let want = materialized(&a, &b, &x, None, Strategy::Combined);
    let want_tail = materialized(&a, &b, &x, Some(&tail), Strategy::Combined);

    // Bare eval (fresh default context) and pooled/threaded contexts.
    let y = (&a * &b * &x).eval();
    assert_eq!(bits(&y), bits(&want), "bare eval");
    let y_tail = (&a * &b * &x + &tail).eval();
    assert_eq!(bits(&y_tail), bits(&want_tail), "tail eval");
    for threads in [1usize, 2] {
        let mut ctx =
            EvalContext::using(Strategy::Combined).with_exec(&pool).with_threads(threads);
        let y = (&a * &b * &x).eval_with(&mut ctx);
        assert_eq!(bits(&y), bits(&want), "pooled eval threads={threads}");
    }

    // A huge fanout flips the arbitration to the materialized fallback;
    // the answer must not move by a single bit.
    let y_mat = (&a * &b * &x).with_fanout(1 << 20).eval();
    assert_eq!(bits(&y_mat), bits(&want), "materialized fallback");
    let y_mat_tail = ((&a * &b * &x).with_fanout(1 << 20) + &tail).eval();
    assert_eq!(bits(&y_mat_tail), bits(&want_tail), "materialized fallback + tail");

    // Plan-cache path: repeated pipelines reuse the shared product plan
    // (hits go up, symbolic builds don't) and still match bitwise.
    let cache = PlanCache::default();
    let mut ctx = EvalContext::new().with_exec(&pool).with_plan_cache(&cache);
    let mut y = vec![0.0; a.rows()];
    for _ in 0..3 {
        ctx.fused_matvec(&a, &b, &x, &mut y);
        assert_eq!(bits(&y), bits(&want), "planned fused");
    }
    let stats = cache.stats();
    ctx.fused_matvec(&a, &b, &x, &mut y);
    let after = cache.stats();
    assert_eq!(bits(&y), bits(&want), "planned fused, warm");
    assert_eq!(after.symbolic_builds, stats.symbolic_builds, "no symbolic rebuild");
    assert!(after.hits > stats.hits, "warm pipeline hits the plan cache");
}

#[test]
fn exact_cancellation_and_empty_rows_are_bit_identical() {
    // A is 4×2 with an empty row 1; B is 2×3. Row 0 of the product
    // cancels exactly in column 0 (1·1 + 1·(−1) = ±0.0): the fused
    // contraction and the materialized product must agree on the sign
    // of that zero, because both fold the same partials in the same
    // order.
    let a = CsrMatrix::from_parts(
        4,
        2,
        vec![0, 2, 2, 3, 5],
        vec![0, 1, 0, 0, 1],
        vec![1.0, 1.0, 2.5, -3.0, 0.5],
    );
    let b = CsrMatrix::from_parts(
        2,
        3,
        vec![0, 2, 4],
        vec![0, 1, 0, 2],
        vec![1.0, 4.0, -1.0, 8.0],
    );
    let x = vec![7.0, -2.0, 1.5];
    let tail = vec![0.25, -0.25, 3.0, -3.0];
    for s in Strategy::ALL {
        let want = materialized(&a, &b, &x, None, s);
        let mut y = vec![0.0; a.rows()];
        EvalContext::using(s).fused_matvec(&a, &b, &x, &mut y);
        assert_eq!(bits(&y), bits(&want), "cancellation, {}", s.name());
        assert_eq!(y[1].to_bits(), 0.0f64.to_bits(), "empty row stays +0.0");
    }
    let want_tail = materialized(&a, &b, &x, Some(&tail), Strategy::Combined);
    let y_tail = (&a * &b * &x + &tail).eval();
    assert_eq!(bits(&y_tail), bits(&want_tail), "cancellation + tail");
}

#[test]
fn nan_payloads_propagate_identically() {
    // A NaN (and an ∞, whose partial sums can collapse to NaN) in the
    // left operand poisons every product entry its row produces; fused
    // and materialized must emit byte-identical payloads. Compared via
    // to_bits — comparing the floats would fail outright, NaN != NaN.
    let (_, b) = operand_pair(Workload::RandomFixed5, 96, 5);
    let a = CsrMatrix::from_parts(
        3,
        96,
        vec![0, 2, 4, 5],
        vec![0, 10, 20, 21, 5],
        vec![f64::NAN, 1.0, f64::INFINITY, -1.0, 2.0],
    );
    let x = probe_vector(b.cols());
    for s in Strategy::ALL {
        let want = materialized(&a, &b, &x, None, s);
        assert!(want.iter().any(|v| v.is_nan()), "probe must actually hit a NaN");
        let mut y = vec![0.0; a.rows()];
        EvalContext::using(s).fused_matvec(&a, &b, &x, &mut y);
        assert_eq!(bits(&y), bits(&want), "NaN propagation, {}", s.name());
    }
    // And through the expression layer on both sides of the arbitration.
    let want = materialized(&a, &b, &x, None, Strategy::Combined);
    let mut ctx = EvalContext::using(Strategy::Combined);
    let y = (&a * &b * &x).eval_with(&mut ctx);
    assert_eq!(bits(&y), bits(&want), "NaN via fused expression");
    let y_mat = (&a * &b * &x).with_fanout(1 << 20).eval_with(&mut ctx);
    assert_eq!(bits(&y_mat), bits(&want), "NaN via materialized fallback");
}

#[test]
fn streamed_chain_matches_materialized_across_strategies_partitions_threads() {
    let pool = ExecPool::new(3);
    for w in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::PowerLawSkew] {
        let (a, b) = operand_pair(w, 120, 7);
        let (c, _) = operand_pair(w, 120, 8);
        assert_eq!(b.cols(), c.rows(), "square workloads compose into a chain");
        let x = probe_vector(c.cols());
        for s in Strategy::ALL {
            let want = materialized_chain(&[&a, &b, &c], &x, s);
            for threads in [1usize, 2, 5] {
                for partition in [Partition::Rows, Partition::Flops, Partition::Model] {
                    let mut ctx = EvalContext::using(s)
                        .with_exec(&pool)
                        .with_threads(threads)
                        .with_partition(partition)
                        .with_machine(default_machine());
                    let factors = [Cow::Borrowed(&a), Cow::Borrowed(&b), Cow::Borrowed(&c)];
                    let mut y = vec![0.0; a.rows()];
                    ctx.streamed_matvec(&factors, &x, &mut y);
                    assert_eq!(
                        bits(&y),
                        bits(&want),
                        "{w:?} {} threads={threads} {partition:?}",
                        s.name()
                    );
                }
            }
        }
    }
    // The four-term sugar lowers through the same DP arbitration; the
    // bare default context must land on the same bits.
    let (a, b) = operand_pair(Workload::RandomFixed5, 120, 7);
    let (c, _) = operand_pair(Workload::RandomFixed5, 120, 8);
    let x = probe_vector(c.cols());
    let want = materialized_chain(&[&a, &b, &c], &x, Strategy::Combined);
    let y = (&a * &b * &c * &x[..]).eval();
    assert_eq!(bits(&y), bits(&want), "4-term sugar, bare eval");
    let y_mat = (&a * &b * &c * &x[..]).with_fanout(1 << 20).eval();
    assert_eq!(bits(&y_mat), bits(&want), "4-term sugar, materialized fallback");
}

#[test]
fn chain_cancellation_negative_zero_and_empty_rows_are_bit_identical() {
    // A is 4×2 with an empty row 1. Row 0 of A·B cancels exactly in
    // column 0 (1·1 + 1·(−1) = ±0.0). B additionally stores an explicit
    // −0.0 in row 1: A's row 2 touches only that B row, so its product
    // entry in column 1 is a lone −0.0 partial — the `!= 0.0` drop rule
    // discards it in the streamed slab exactly as the materialized
    // product does, or the chain's next hop would see different
    // patterns on the two sides.
    let a = CsrMatrix::from_parts(
        4,
        2,
        vec![0, 2, 2, 3, 5],
        vec![0, 1, 1, 0, 1],
        vec![1.0, 1.0, 2.0, -3.0, 0.5],
    );
    let b = CsrMatrix::from_parts(
        2,
        3,
        vec![0, 2, 5],
        vec![0, 1, 0, 1, 2],
        vec![1.0, 4.0, -1.0, -0.0, 8.0],
    );
    let c = CsrMatrix::from_parts(
        3,
        3,
        vec![0, 2, 3, 6],
        vec![0, 2, 1, 0, 1, 2],
        vec![2.0, -1.0, 3.0, 0.5, -0.25, 1.0],
    );
    // Pin the premise: the lone −0.0 partial is dropped from the
    // materialized intermediate (row 2 keeps two of three candidates).
    let m1 = spmmm(&a, &b, Strategy::Combined);
    assert_eq!(m1.row(2).0.len(), 2, "lone -0.0 partial must be dropped");
    let x = vec![7.0, -2.0, 1.5];
    let pool = ExecPool::new(2);
    for s in Strategy::ALL {
        let want = materialized_chain(&[&a, &b, &c], &x, s);
        for threads in [1usize, 2, 5] {
            let mut ctx = EvalContext::using(s).with_exec(&pool).with_threads(threads);
            let factors = [Cow::Borrowed(&a), Cow::Borrowed(&b), Cow::Borrowed(&c)];
            let mut y = vec![0.0; a.rows()];
            ctx.streamed_matvec(&factors, &x, &mut y);
            assert_eq!(bits(&y), bits(&want), "chain cancellation, {} t={threads}", s.name());
            assert_eq!(y[1].to_bits(), 0.0f64.to_bits(), "empty row stays +0.0");
        }
    }
}

#[test]
fn chain_nan_payloads_propagate_identically() {
    // A NaN (and an ∞) in the middle factor poisons every chain entry
    // its row reaches; streamed and materialize-every-hop must emit
    // byte-identical payloads. Compared via to_bits — NaN != NaN.
    let (c, _) = operand_pair(Workload::RandomFixed5, 96, 5);
    let a = CsrMatrix::from_parts(
        3,
        3,
        vec![0, 1, 2, 4],
        vec![0, 1, 0, 2],
        vec![1.0, -2.0, 1.0, 0.5],
    );
    let b = CsrMatrix::from_parts(
        3,
        96,
        vec![0, 2, 4, 5],
        vec![0, 10, 20, 21, 5],
        vec![f64::NAN, 1.0, f64::INFINITY, -1.0, 2.0],
    );
    let x = probe_vector(c.cols());
    for s in Strategy::ALL {
        let want = materialized_chain(&[&a, &b, &c], &x, s);
        assert!(want.iter().any(|v| v.is_nan()), "probe must actually hit a NaN");
        let factors = [Cow::Borrowed(&a), Cow::Borrowed(&b), Cow::Borrowed(&c)];
        let mut y = vec![0.0; a.rows()];
        EvalContext::using(s).streamed_matvec(&factors, &x, &mut y);
        assert_eq!(bits(&y), bits(&want), "chain NaN propagation, {}", s.name());
    }
    // And through the expression layer on both sides of the arbitration.
    let want = materialized_chain(&[&a, &b, &c], &x, Strategy::Combined);
    let mut ctx = EvalContext::using(Strategy::Combined);
    let y = (&a * &b * &c * &x[..]).eval_with(&mut ctx);
    assert_eq!(bits(&y), bits(&want), "chain NaN via streamed expression");
    let y_mat = (&a * &b * &c * &x[..]).with_fanout(1 << 20).eval_with(&mut ctx);
    assert_eq!(bits(&y_mat), bits(&want), "chain NaN via materialized fallback");
}
