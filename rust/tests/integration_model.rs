//! Integration: the model-guided analysis — paper numbers, simulator
//! behaviour across cache regimes, prediction coherence.

use blazert::gen::{operand_pair, Workload};
use blazert::kernels::gustavson::pure_row_major;
use blazert::kernels::{spmmm_traced, Strategy};
use blazert::model::balance::{PureComputeTraffic, GUSTAVSON_INNER_BALANCE};
use blazert::model::{lightspeed, predict, Machine};
use blazert::simulator::Hierarchy;
use blazert::sparse::SparseShape;

#[test]
fn paper_section_iv_numbers() {
    let m = Machine::sandy_bridge_i7_2600();
    assert_eq!(m.peak_flops(), 7.6e9, "1 mul + 1 add at 3.8 GHz");
    let l1 = lightspeed(&m, Some(0), GUSTAVSON_INNER_BALANCE);
    assert!((l1 - 3.8e9).abs() < 1e6, "L1 limit 3800 MFlop/s");
    let mem = lightspeed(&m, None, GUSTAVSON_INNER_BALANCE);
    assert!((mem / 1e6 - 1156.0).abs() < 5.0, "memory limit ~1140-1156 MFlop/s");
}

#[test]
fn traced_inner_balance_matches_hand_analysis() {
    // The CountingTracer-style accounting of the pure kernel must agree
    // with the symbolic PureComputeTraffic model exactly.
    let (a, b) = operand_pair(Workload::FiveBandFd, 1024, 3);
    let expected = PureComputeTraffic::of(&a, &b);
    let mut tr = blazert::kernels::tracer::CountingTracer::default();
    let _ = pure_row_major(&a, &b, &mut tr);
    assert_eq!(tr.flops, expected.flops);
    assert_eq!(tr.traffic(), expected.total_bytes());
    assert!((expected.inner_balance() - 16.0).abs() < 1e-12);
}

#[test]
fn cache_regimes_order_memory_traffic() {
    // Growing N through the LLC must monotonically grow per-flop memory
    // traffic; in-cache sizes keep it near compulsory-only.
    let m = Machine::sandy_bridge_i7_2600();
    let mut balances = Vec::new();
    for n in [1024usize, 16384, 147456] {
        let (a, b) = operand_pair(Workload::RandomFixed5, n, 5);
        let mut h = Hierarchy::of_machine(&m);
        let _ = pure_row_major(&a, &b, &mut h);
        balances.push(h.report().mem_balance());
    }
    // In-cache sizes are compulsory-dominated (near-equal balances, 5%
    // tolerance); the beyond-LLC size must be clearly worse.
    assert!(
        balances[0] <= balances[1] * 1.05 && balances[1] < balances[2] * 0.8,
        "memory balance must grow with N: {balances:?}"
    );
}

#[test]
fn fd_streams_better_than_random_beyond_llc() {
    // The paper's Figure 2 vs 3 story: beyond the LLC the FD workload
    // keeps lower memory balance (prefetch/streaming-friendly structure;
    // here: compulsory-dominated reuse) than the random workload.
    let m = Machine::sandy_bridge_i7_2600();
    let n = 147456;
    let mut hf = Hierarchy::of_machine(&m);
    let (a, b) = operand_pair(Workload::FiveBandFd, n, 5);
    let _ = pure_row_major(&a, &b, &mut hf);
    let mut hr = Hierarchy::of_machine(&m);
    let (ar, br) = operand_pair(Workload::RandomFixed5, n, 5);
    let _ = pure_row_major(&ar, &br, &mut hr);
    assert!(
        hf.report().mem_balance() < hr.report().mem_balance(),
        "FD {} vs random {}",
        hf.report().mem_balance(),
        hr.report().mem_balance()
    );
}

#[test]
fn prediction_is_min_of_paths() {
    let m = Machine::sandy_bridge_i7_2600();
    let (a, b) = operand_pair(Workload::RandomFixed5, 8192, 9);
    let mut h = Hierarchy::of_machine(&m);
    let _ = spmmm_traced(&a, &b, Strategy::Combined, &mut h);
    let p = predict(&m, &h.report());
    for path in &p.paths {
        assert!(p.predicted <= path.ceiling + 1.0);
    }
    assert!(p.predicted <= p.peak);
    assert!(p.paths.iter().any(|pp| pp.name == "MEM"));
    assert!(p.efficiency(p.predicted) > 0.999);
}

#[test]
fn store_strategies_differ_in_traffic_not_result() {
    // The model-guided view of §IV-B: MinMax scans more bytes than Sort
    // on scattered rows; BruteForce dwarfs both.
    let m = Machine::sandy_bridge_i7_2600();
    let (a, b) = operand_pair(Workload::RandomFixed5, 2048, 13);
    let mut traffic = std::collections::HashMap::new();
    for s in [Strategy::BruteForceDouble, Strategy::MinMax, Strategy::Sort] {
        let mut h = Hierarchy::of_machine(&m);
        let c = spmmm_traced(&a, &b, s, &mut h);
        traffic.insert(s.name(), (h.load_ops + h.store_ops, c.nnz()));
    }
    let bf = traffic["BruteForce-double"].0;
    let mm = traffic["MinMax"].0;
    let so = traffic["Sort"].0;
    assert!(bf > mm, "BruteForce {bf} > MinMax {mm}");
    assert!(mm > so, "MinMax {mm} > Sort {so} on scattered rows");
    let nnzs: Vec<usize> = traffic.values().map(|v| v.1).collect();
    assert!(nnzs.windows(2).all(|w| w[0] == w[1]), "identical results");
}

#[test]
fn warm_cache_reduces_misses() {
    // The paper preloads in-cache data; warming must not increase
    // and should strictly decrease cold misses for a cache-resident run.
    let m = Machine::sandy_bridge_i7_2600();
    let (a, b) = operand_pair(Workload::FiveBandFd, 1024, 3);
    let mut cold = Hierarchy::of_machine(&m);
    let _ = pure_row_major(&a, &b, &mut cold);
    let cold_mem = cold.mem_bytes;
    // Second run on the same hierarchy = warm.
    let before = cold.mem_bytes;
    let _ = pure_row_major(&a, &b, &mut cold);
    let warm_mem = cold.mem_bytes - before;
    assert!(warm_mem < cold_mem / 5, "warm {warm_mem} vs cold {cold_mem}");
}
