//! Integration: the PJRT runtime + BSR/XLA path. These tests need
//! `make artifacts`; without artifacts they print a notice and pass
//! vacuously (so `cargo test` works on a fresh checkout).

use blazert::bsr::{bsr_spmmm, BsrMatrix, NativeBackend, TileBackend};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::{spmmm, Strategy};
use blazert::runtime::{Runtime, TileEngine};
use blazert::sparse::{DenseMatrix, SparseShape};
use blazert::util::rng::Pcg64;

fn engine_or_skip(test: &str) -> Option<TileEngine> {
    if !Runtime::artifacts_available() {
        eprintln!("[{test}] artifacts/ missing — run `make artifacts`; skipping");
        return None;
    }
    Some(TileEngine::load_default().expect("engine loads"))
}

#[test]
fn tile_mma_matches_native_backend() {
    let Some(mut engine) = engine_or_skip("tile_mma_matches_native_backend") else {
        return;
    };
    let te = engine.tile_elems();
    let mut rng = Pcg64::new(1);
    // 100 tiles: exercises batch splitting (64 + padded 36).
    let n = 100;
    let gen = |rng: &mut Pcg64| -> Vec<f32> {
        (0..n * te).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect()
    };
    let a = gen(&mut rng);
    let b = gen(&mut rng);
    let acc = gen(&mut rng);
    let xla = engine.mma(&a, &b, &acc).expect("xla mma");
    let mut native = NativeBackend { tile: engine.tile };
    let expect = native.mma(&a, &b, &acc).expect("native mma");
    assert_eq!(xla.len(), expect.len());
    let max_diff = xla
        .iter()
        .zip(&expect)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-2, "f32 tile mma diff {max_diff}");
    assert!(engine.calls >= 2, "batch splitting happened");
    assert!(engine.padded_slots > 0, "tail was padded");
}

#[test]
fn group_mma_matches_reference() {
    let Some(mut engine) = engine_or_skip("group_mma_matches_reference") else {
        return;
    };
    let te = engine.tile_elems();
    let want = engine.groups * engine.group_k * te;
    let mut rng = Pcg64::new(2);
    let a: Vec<f32> = (0..want).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..want).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
    let out = engine.group_mma(&a, &b).expect("group mma");
    assert_eq!(out.len(), engine.groups * te);
    // Reference: sum over k of native tile products.
    let mut native = NativeBackend { tile: engine.tile };
    let mut expect = vec![0f32; engine.groups * te];
    for g in 0..engine.groups {
        let mut acc = vec![0f32; te];
        for k in 0..engine.group_k {
            let off = (g * engine.group_k + k) * te;
            acc = native.mma(&a[off..off + te], &b[off..off + te], &acc).unwrap();
        }
        expect[g * te..(g + 1) * te].copy_from_slice(&acc);
    }
    let max_diff = out.iter().zip(&expect).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_diff < 1e-2, "group mma diff {max_diff}");
}

#[test]
fn dense_mm_smoke() {
    let Some(mut engine) = engine_or_skip("dense_mm_smoke") else {
        return;
    };
    let n = engine.dense_n;
    // Identity x M == M.
    let mut eye = vec![0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let mut rng = Pcg64::new(3);
    let m: Vec<f32> = (0..n * n).map(|_| rng.f64_range(-1.0, 1.0) as f32).collect();
    let out = engine.dense_mm(&eye, &m).expect("dense mm");
    let max_diff = out.iter().zip(&m).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
    assert!(max_diff < 1e-5);
}

#[test]
fn bsr_spmmm_xla_equals_scalar_kernel() {
    let Some(mut engine) = engine_or_skip("bsr_spmmm_xla_equals_scalar_kernel") else {
        return;
    };
    let tile = engine.tile;
    for (w, n) in [(Workload::FiveBandFd, 1024), (Workload::RandomFixed5, 512)] {
        let (a, b) = operand_pair(w, n, 17);
        let ab = BsrMatrix::from_csr(&a, tile);
        let bb = BsrMatrix::from_csr(&b, tile);
        let c = bsr_spmmm(&ab, &bb, &mut engine).expect("bsr spmmm");
        let reference = spmmm(&a, &b, Strategy::Combined);
        let d1 = DenseMatrix::from_csr(&c.to_csr());
        let d2 = DenseMatrix::from_csr(&reference);
        let rel = d1.max_abs_diff(&d2) / d2.frobenius().max(1.0);
        assert!(rel < 1e-5, "{w:?}: rel err {rel}");
        assert_eq!(c.to_csr().nnz(), reference.nnz(), "{w:?}: structural match");
    }
}

#[test]
fn runtime_rejects_shape_mismatches() {
    let Some(mut engine) = engine_or_skip("runtime_rejects_shape_mismatches") else {
        return;
    };
    let te = engine.tile_elems();
    // Wrong multiple.
    assert!(engine.mma(&vec![0f32; te + 1], &vec![0f32; te + 1], &vec![0f32; te + 1]).is_err());
    // Mismatched lengths.
    assert!(engine.mma(&vec![0f32; te], &vec![0f32; 2 * te], &vec![0f32; te]).is_err());
    // Wrong group geometry.
    assert!(engine.group_mma(&vec![0f32; te], &vec![0f32; te]).is_err());
}

#[test]
fn manifest_geometry_sane() {
    if !Runtime::artifacts_available() {
        eprintln!("[manifest_geometry_sane] artifacts missing; skipping");
        return;
    }
    let rt = Runtime::load_default().expect("runtime");
    let m = rt.manifest();
    for name in ["tile_mma", "tile_group_mma", "dense_mm"] {
        assert!(m.entries.contains_key(name), "{name} in manifest");
    }
    assert_eq!(m.param("tile"), Some(32));
    assert!(m.param("batch").unwrap() > 0);
}
