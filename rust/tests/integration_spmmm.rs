//! Integration: every spMMM path (kernels × strategies × workloads ×
//! baselines × storage orders) against the dense oracle and each other.

use blazert::baselines::Library;
use blazert::gen::{banded, fd_poisson_2d, operand_pair, random_fixed_per_row, Workload};
use blazert::kernels::classic::spmmm_classic;
use blazert::kernels::{spmmm, spmmm_csc, spmmm_csr_csc, NullTracer, Strategy};
use blazert::sparse::convert::{csc_to_csr, csr_to_csc};
use blazert::sparse::{DenseMatrix, SparseShape};

fn oracle(a: &blazert::CsrMatrix, b: &blazert::CsrMatrix) -> DenseMatrix {
    DenseMatrix::from_csr(a).matmul(&DenseMatrix::from_csr(b))
}

#[test]
fn every_strategy_on_every_workload() {
    for w in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::RandomFill01Pct] {
        let (a, b) = operand_pair(w, 400, 3);
        let expect = oracle(&a, &b);
        for s in Strategy::ALL {
            let c = spmmm(&a, &b, s);
            assert!(
                DenseMatrix::from_csr(&c).max_abs_diff(&expect) < 1e-10,
                "{w:?} {}",
                s.name()
            );
        }
    }
}

#[test]
fn classic_and_conversion_paths() {
    let (a, b) = operand_pair(Workload::RandomFixed5, 300, 11);
    let b_csc = csr_to_csc(&b);
    let expect = oracle(&a, &b);
    let classic = spmmm_classic(&a, &b_csc, &mut NullTracer);
    assert!(DenseMatrix::from_csr(&classic).max_abs_diff(&expect) < 1e-10);
    let with_conv = spmmm_csr_csc(&a, &b_csc, Strategy::Combined);
    assert!(DenseMatrix::from_csr(&with_conv).max_abs_diff(&expect) < 1e-10);
    let csc_path = spmmm_csc(&csr_to_csc(&a), &b_csc, Strategy::Combined);
    assert!(DenseMatrix::from_csc(&csc_path).max_abs_diff(&expect) < 1e-10);
}

#[test]
fn all_libraries_and_all_orders_agree() {
    for w in [Workload::FiveBandFd, Workload::RandomFixed5] {
        let (a, b) = operand_pair(w, 256, 5);
        let b_csc = csr_to_csc(&b);
        let reference = spmmm(&a, &b, Strategy::Combined);
        for lib in Library::ALL {
            assert!(lib.multiply_csr_csr(&a, &b).approx_eq(&reference, 1e-12), "{}", lib.name());
            assert!(lib.multiply_csr_csc(&a, &b_csc).approx_eq(&reference, 1e-12), "{}", lib.name());
        }
    }
}

#[test]
fn fd_squared_structure() {
    // A² of the 5-point stencil is the 9-point-plus pattern: row nnz <=
    // 13, bandwidth doubles, symmetric.
    let k = 20;
    let a = fd_poisson_2d(k);
    let c = spmmm(&a, &a, Strategy::Combined);
    for r in 0..c.rows() {
        assert!(c.row_nnz(r) <= 13);
    }
    let ct = c.transpose();
    assert!(c.approx_eq(&ct, 1e-12), "A^2 symmetric");
}

#[test]
fn chained_band_products_grow_bandwidth() {
    let n = 200;
    let t = banded(n, &[-1, 0, 1], 9);
    let t2 = spmmm(&t, &t, Strategy::Combined);
    let t4 = spmmm(&t2, &t2, Strategy::Combined);
    // Tridiagonal^2 -> pentadiagonal -> 9-diagonal.
    for r in 5..n - 5 {
        assert_eq!(t2.row_nnz(r), 5, "row {r}");
        assert_eq!(t4.row_nnz(r), 9, "row {r}");
    }
}

#[test]
fn rectangular_chains() {
    let a = random_fixed_per_row(40, 100, 5, 1);
    let b = random_fixed_per_row(100, 7, 3, 2);
    let c = spmmm(&a, &b, Strategy::Combined);
    assert_eq!((c.rows(), c.cols()), (40, 7));
    assert!(DenseMatrix::from_csr(&c).max_abs_diff(&oracle(&a, &b)) < 1e-10);
}

#[test]
fn empty_and_identity_cases() {
    // Zero matrix times anything is structurally empty.
    let z = blazert::CsrMatrix::from_parts(50, 50, vec![0; 51], vec![], vec![]);
    let r = random_fixed_per_row(50, 50, 5, 8);
    for s in Strategy::ALL {
        assert_eq!(spmmm(&z, &r, s).nnz(), 0);
        assert_eq!(spmmm(&r, &z, s).nnz(), 0);
    }
    // Identity preserves.
    let eye = DenseMatrix::identity(50).to_csr();
    let c = spmmm(&eye, &r, Strategy::Combined);
    assert!(c.approx_eq(&r, 1e-15));
    let c2 = spmmm(&r, &eye, Strategy::Combined);
    assert!(c2.approx_eq(&r, 1e-15));
}

#[test]
fn conversion_round_trips_on_workloads() {
    for w in [Workload::FiveBandFd, Workload::RandomFixed5] {
        let (a, _) = operand_pair(w, 500, 21);
        let back = csc_to_csr(&csr_to_csc(&a));
        assert!(back.approx_eq(&a, 0.0));
    }
}

#[test]
fn combined_counters_reflect_workload() {
    // FD rows are tight -> MinMax path dominates at small N; random rows
    // scatter -> Sort path dominates at large N.
    use blazert::kernels::gustavson::rows_into;
    use blazert::kernels::store::{Accumulator, Combined};

    let a = fd_poisson_2d(10); // N=100: region ~4*10=40 vs 2*13=26 -> mixed
    let mut acc = Combined::new(a.cols());
    let mut out = blazert::CsrMatrix::new(a.rows(), a.cols());
    rows_into(&a, &a, &mut acc, &mut out, &mut NullTracer);
    assert_eq!(acc.minmax_rows + acc.sort_rows, 100);

    let r = random_fixed_per_row(400, 400, 5, 2);
    let mut acc2 = Combined::new(400);
    let mut out2 = blazert::CsrMatrix::new(400, 400);
    rows_into(&r, &r, &mut acc2, &mut out2, &mut NullTracer);
    assert!(acc2.sort_rows > acc2.minmax_rows, "random large-N prefers Sort");
}
