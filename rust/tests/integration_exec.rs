//! Integration tests for the persistent execution engine: bit-identity
//! of the size-then-fill parallel kernel against the serial kernel for
//! every storing strategy, partition, and thread count — including
//! empty slabs, a single hot row, and threads > rows — plus
//! pool/workspace reuse across calls and expression-layer integration.

use blazert::exec::{ExecPool, Partition};
use blazert::expr::{EvalContext, Expression, SparseOperand};
use blazert::gen::{operand_pair, random_power_law, Workload};
use blazert::kernels::parallel::{par_spmmm, par_spmmm_into, par_spmmm_with};
use blazert::kernels::{spmmm, Strategy};
use blazert::model::Machine;
use blazert::sparse::{CsrMatrix, SparseShape};

#[test]
fn bit_identity_all_strategies_partitions_threads() {
    let pool = ExecPool::new(3);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut out = CsrMatrix::new(0, 0);
    for workload in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::PowerLawSkew] {
        let (a, b) = operand_pair(workload, 240, 17);
        for strategy in Strategy::ALL {
            let serial = spmmm(&a, &b, strategy);
            for partition in Partition::ALL {
                for threads in [1usize, 2, 5, 16] {
                    par_spmmm_into(
                        &pool, &a, &b, threads, strategy, partition, &machine, &mut out,
                    );
                    assert!(
                        out.approx_eq(&serial, 0.0),
                        "{workload:?} {} {partition:?} threads={threads}",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn single_hot_row_and_empty_slabs() {
    // Row 0 holds every column; all other rows are empty — the
    // flop-balanced cut assigns the hot row one slab and leaves later
    // slabs empty, which must still produce a bit-identical result.
    let n = 64usize;
    let mut a = CsrMatrix::new(n, n);
    for c in 0..n {
        a.append(c, (c + 1) as f64);
    }
    a.finalize_row();
    for _ in 1..n {
        a.finalize_row();
    }
    let b = random_power_law(n, n, 16, 1.0, 3);
    for strategy in [Strategy::MinMax, Strategy::Sort, Strategy::Combined] {
        let serial = spmmm(&a, &b, strategy);
        for threads in [2usize, 8, n, 4 * n] {
            let par = par_spmmm_with(&a, &b, threads, strategy);
            assert!(par.approx_eq(&serial, 0.0), "{} threads={threads}", strategy.name());
        }
    }
}

#[test]
fn threads_exceed_rows_on_tiny_matrices() {
    for rows in [1usize, 2, 3] {
        let (a, b) = operand_pair(Workload::RandomFixed5, rows, 9);
        let serial = spmmm(&a, &b, Strategy::Combined);
        let par = par_spmmm(&a, &b, 64);
        assert!(par.approx_eq(&serial, 0.0), "rows={rows}");
    }
}

#[test]
fn empty_operands_all_partitions() {
    let pool = ExecPool::new(2);
    let machine = Machine::sandy_bridge_i7_2600();
    let z = CsrMatrix::from_parts(7, 7, vec![0; 8], vec![], vec![]);
    let mut out = CsrMatrix::new(0, 0);
    for partition in Partition::ALL {
        par_spmmm_into(&pool, &z, &z, 4, Strategy::Combined, partition, &machine, &mut out);
        assert_eq!(out.nnz(), 0, "{partition:?}");
        assert!(out.is_finalized());
        assert_eq!(out.rows(), 7);
    }
}

#[test]
fn pool_is_reused_across_many_calls_and_sizes() {
    // One pool, many products of varying shape: workspaces grow
    // monotonically and results stay exact throughout.
    let pool = ExecPool::new(2);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut out = CsrMatrix::new(0, 0);
    for n in [30usize, 120, 60, 200, 40] {
        let (a, b) = operand_pair(Workload::RandomFixed5, n, n as u64);
        let serial = spmmm(&a, &b, Strategy::Combined);
        par_spmmm_into(
            &pool,
            &a,
            &b,
            2,
            Strategy::Combined,
            Partition::Flops,
            &machine,
            &mut out,
        );
        assert!(out.approx_eq(&serial, 0.0), "n={n}");
    }
}

#[test]
fn expression_trees_evaluate_through_the_pool() {
    let pool = ExecPool::new(2);
    let (a, b) = operand_pair(Workload::RandomFixed5, 80, 21);
    let c = b.clone();
    let reference = {
        let ab = spmmm(&a, &b, Strategy::Combined);
        spmmm(&ab, &c, Strategy::Combined)
    };
    // Chained product through a pooled parallel context.
    let mut ctx = EvalContext::new().with_exec(&pool).with_threads(2);
    let got = (&a * &b * &c).eval_with(&mut ctx);
    assert!(got.approx_eq(&reference, 0.0));
    // Pooled assign_to reuses the output and stays exact on repeat.
    let mut out = CsrMatrix::new(0, 0);
    let prod = &a * &b;
    prod.assign_to(&mut out, &mut ctx);
    let cap = out.capacity();
    prod.assign_to(&mut out, &mut ctx);
    assert_eq!(out.capacity(), cap, "warm assignment allocates nothing");
    assert!(out.approx_eq(&spmmm(&a, &b, Strategy::Combined), 0.0));
}

#[test]
fn csc_leaf_assignment_reuses_buffers() {
    use blazert::sparse::convert::csr_to_csc;
    let (a, _) = operand_pair(Workload::RandomFixed5, 60, 33);
    let a_csc = csr_to_csc(&a);
    let mut out = CsrMatrix::new(0, 0);
    let mut ctx = EvalContext::new();
    a_csc.assign_to(&mut out, &mut ctx);
    assert!(out.approx_eq(&a, 0.0), "CSC leaf converts to the CSR value");
    let cap = out.capacity();
    a_csc.assign_to(&mut out, &mut ctx);
    assert!(out.approx_eq(&a, 0.0));
    assert_eq!(out.capacity(), cap, "leaf conversion reuses the buffers");
}
