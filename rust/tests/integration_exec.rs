//! Integration tests for the persistent execution engine: bit-identity
//! of the size-then-fill parallel kernel against the serial kernel for
//! every storing strategy, partition, and thread count — including
//! empty slabs, a single hot row, and threads > rows — plus
//! pool/workspace reuse across calls, expression-layer integration, and
//! the symbolic/numeric plan split (planned evaluation bit-identical to
//! unplanned everywhere; cache hits perform no symbolic work).

use std::sync::Arc;

use blazert::exec::{ExecPool, Partition, Workspace};
use blazert::expr::{EvalContext, Expression, SparseOperand};
use blazert::gen::{operand_pair, random_power_law, Workload};
use blazert::kernels::parallel::{par_planned_fill, par_spmmm, par_spmmm_into, par_spmmm_with};
use blazert::kernels::{
    planned_fill_csr_csc, planned_fill_serial, planned_fill_serial_csc, spmmm, spmmm_csc,
    spmmm_csr_csc, Strategy,
};
use blazert::model::Machine;
use blazert::plan::{PlanCache, PlanKey, PlanStore, SpmmmPlan};
use blazert::sparse::convert::csr_to_csc;
use blazert::sparse::{CscMatrix, CsrMatrix, SparseShape};

#[test]
fn bit_identity_all_strategies_partitions_threads() {
    let pool = ExecPool::new(3);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut out = CsrMatrix::new(0, 0);
    for workload in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::PowerLawSkew] {
        let (a, b) = operand_pair(workload, 240, 17);
        for strategy in Strategy::ALL {
            let serial = spmmm(&a, &b, strategy);
            for partition in Partition::ALL {
                for threads in [1usize, 2, 5, 16] {
                    par_spmmm_into(
                        &pool, &a, &b, threads, strategy, partition, &machine, &mut out,
                    );
                    assert!(
                        out.approx_eq(&serial, 0.0),
                        "{workload:?} {} {partition:?} threads={threads}",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn single_hot_row_and_empty_slabs() {
    // Row 0 holds every column; all other rows are empty — the
    // flop-balanced cut assigns the hot row one slab and leaves later
    // slabs empty, which must still produce a bit-identical result.
    let n = 64usize;
    let mut a = CsrMatrix::new(n, n);
    for c in 0..n {
        a.append(c, (c + 1) as f64);
    }
    a.finalize_row();
    for _ in 1..n {
        a.finalize_row();
    }
    let b = random_power_law(n, n, 16, 1.0, 3);
    for strategy in [Strategy::MinMax, Strategy::Sort, Strategy::Combined] {
        let serial = spmmm(&a, &b, strategy);
        for threads in [2usize, 8, n, 4 * n] {
            let par = par_spmmm_with(&a, &b, threads, strategy);
            assert!(par.approx_eq(&serial, 0.0), "{} threads={threads}", strategy.name());
        }
    }
}

#[test]
fn threads_exceed_rows_on_tiny_matrices() {
    for rows in [1usize, 2, 3] {
        let (a, b) = operand_pair(Workload::RandomFixed5, rows, 9);
        let serial = spmmm(&a, &b, Strategy::Combined);
        let par = par_spmmm(&a, &b, 64);
        assert!(par.approx_eq(&serial, 0.0), "rows={rows}");
    }
}

#[test]
fn empty_operands_all_partitions() {
    let pool = ExecPool::new(2);
    let machine = Machine::sandy_bridge_i7_2600();
    let z = CsrMatrix::from_parts(7, 7, vec![0; 8], vec![], vec![]);
    let mut out = CsrMatrix::new(0, 0);
    for partition in Partition::ALL {
        par_spmmm_into(&pool, &z, &z, 4, Strategy::Combined, partition, &machine, &mut out);
        assert_eq!(out.nnz(), 0, "{partition:?}");
        assert!(out.is_finalized());
        assert_eq!(out.rows(), 7);
    }
}

#[test]
fn pool_is_reused_across_many_calls_and_sizes() {
    // One pool, many products of varying shape: workspaces grow
    // monotonically and results stay exact throughout.
    let pool = ExecPool::new(2);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut out = CsrMatrix::new(0, 0);
    for n in [30usize, 120, 60, 200, 40] {
        let (a, b) = operand_pair(Workload::RandomFixed5, n, n as u64);
        let serial = spmmm(&a, &b, Strategy::Combined);
        par_spmmm_into(
            &pool,
            &a,
            &b,
            2,
            Strategy::Combined,
            Partition::Flops,
            &machine,
            &mut out,
        );
        assert!(out.approx_eq(&serial, 0.0), "n={n}");
    }
}

#[test]
fn expression_trees_evaluate_through_the_pool() {
    let pool = ExecPool::new(2);
    let (a, b) = operand_pair(Workload::RandomFixed5, 80, 21);
    let c = b.clone();
    let reference = {
        let ab = spmmm(&a, &b, Strategy::Combined);
        spmmm(&ab, &c, Strategy::Combined)
    };
    // Chained product through a pooled parallel context.
    let mut ctx = EvalContext::new().with_exec(&pool).with_threads(2);
    let got = (&a * &b * &c).eval_with(&mut ctx);
    assert!(got.approx_eq(&reference, 0.0));
    // Pooled assign_to reuses the output and stays exact on repeat.
    let mut out = CsrMatrix::new(0, 0);
    let prod = &a * &b;
    prod.assign_to(&mut out, &mut ctx);
    let cap = out.capacity();
    prod.assign_to(&mut out, &mut ctx);
    assert_eq!(out.capacity(), cap, "warm assignment allocates nothing");
    assert!(out.approx_eq(&spmmm(&a, &b, Strategy::Combined), 0.0));
}

/// Property: planned evaluation is bit-identical to every unplanned
/// strategy, for every partition and thread count, on every workload —
/// the planned numeric phase must be indistinguishable from the kernels
/// it replaces.
#[test]
fn planned_bit_identical_across_strategies_partitions_threads() {
    let pool = ExecPool::new(3);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut ws = Workspace::new();
    let mut temp = Vec::new();
    let mut out = CsrMatrix::new(0, 0);
    for workload in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::PowerLawSkew] {
        let (a, b) = operand_pair(workload, 240, 17);
        // Every unplanned strategy agrees with the reference bit-exactly…
        let reference = spmmm(&a, &b, Strategy::Combined);
        for strategy in Strategy::ALL {
            let c = spmmm(&a, &b, strategy);
            assert!(c.approx_eq(&reference, 0.0), "{workload:?} {}", strategy.name());
        }
        // …so one planned-vs-reference check per (partition, threads)
        // covers planned-vs-every-strategy.
        for partition in Partition::ALL {
            for threads in [1usize, 2, 5, 16] {
                let key = PlanKey::of(&machine, &a, &b, threads, partition);
                let plan = SpmmmPlan::build(&machine, &a, &b, key, &mut ws);
                if threads > 1 {
                    par_planned_fill(&pool, &plan, &a, &b, &mut out);
                } else {
                    planned_fill_serial(&plan, &a, &b, &mut temp, &mut out);
                }
                assert!(
                    out.approx_eq(&reference, 0.0),
                    "{workload:?} {partition:?} threads={threads}"
                );
            }
        }
    }
}

/// Exact cancellation and empty rows: the structural pattern keeps the
/// cancelled positions, the numeric compaction must drop them — on the
/// serial and the parallel planned path alike.
#[test]
fn planned_cancellation_and_empty_rows() {
    let machine = Machine::sandy_bridge_i7_2600();
    let pool = ExecPool::new(2);
    // B has two identical rows; row 0 of A multiplies them with opposite
    // signs (exact cancellation), rows 1/3 are empty.
    let mut b = CsrMatrix::new(2, 8);
    for c in [0usize, 2, 5] {
        b.append(c, 1.5);
    }
    b.finalize_row();
    for c in [0usize, 2, 5] {
        b.append(c, 1.5);
    }
    b.finalize_row();
    let mut a = CsrMatrix::new(4, 2);
    a.append(0, 1.0);
    a.append(1, -1.0);
    a.finalize_row();
    a.finalize_row();
    a.append(1, 2.0);
    a.finalize_row();
    a.finalize_row();
    let reference = spmmm(&a, &b, Strategy::Combined);
    assert_eq!(reference.row_nnz(0), 0, "row 0 cancels exactly");
    assert_eq!(reference.row_nnz(2), 3);
    let mut ws = Workspace::new();
    let mut out = CsrMatrix::new(0, 0);
    for partition in Partition::ALL {
        for threads in [1usize, 2, 4] {
            let key = PlanKey::of(&machine, &a, &b, threads, partition);
            let plan = SpmmmPlan::build(&machine, &a, &b, key, &mut ws);
            assert_eq!(plan.pattern_nnz(), 6, "pattern is cancellation-blind");
            if threads > 1 {
                par_planned_fill(&pool, &plan, &a, &b, &mut out);
            } else {
                planned_fill_serial(&plan, &a, &b, &mut ws.plan_temp, &mut out);
            }
            assert!(out.approx_eq(&reference, 0.0), "{partition:?} threads={threads}");
            assert_eq!(out.nnz(), 3, "cancelled slack compacted away");
        }
    }
}

/// The headline counter proof: once a plan is cached, re-evaluating the
/// expression performs **no symbolic phase** — `symbolic_builds` stays
/// flat while `hits` counts every warm evaluation.
#[test]
fn plan_cache_hits_skip_the_symbolic_phase() {
    let pool = ExecPool::new(2);
    let cache = PlanCache::default();
    let (a, b) = operand_pair(Workload::FiveBandFd, 240, 5);
    let reference = spmmm(&a, &b, Strategy::Combined);
    let mut out = CsrMatrix::new(0, 0);
    for threads in [1usize, 2] {
        let mut ctx = EvalContext::new()
            .with_exec(&pool)
            .with_threads(threads)
            .with_plan_cache(&cache);
        let prod = &a * &b;
        // Unplanned first sight, then one symbolic build on repeat.
        prod.assign_to(&mut out, &mut ctx);
        prod.assign_to(&mut out, &mut ctx);
        let builds = cache.stats().symbolic_builds;
        let hits = cache.stats().hits;
        for i in 0..4 {
            prod.assign_to(&mut out, &mut ctx);
            assert!(out.approx_eq(&reference, 0.0), "threads={threads} rep={i}");
        }
        let stats = cache.stats();
        assert_eq!(stats.symbolic_builds, builds, "cache hits must not re-run symbolic");
        assert_eq!(stats.hits, hits + 4, "every warm evaluation is a hit");
    }
}

/// Values may change freely under a fixed pattern: the fingerprint (and
/// the cached plan) only track structure, and the refill picks up the
/// new values — the iterative-scheme contract.
#[test]
fn plan_survives_value_changes_under_fixed_pattern() {
    let machine = Machine::sandy_bridge_i7_2600();
    let (a, b) = operand_pair(Workload::RandomFixed5, 120, 23);
    let scaled = CsrMatrix::from_parts(
        a.rows(),
        a.cols(),
        a.row_ptr().to_vec(),
        a.col_idx().to_vec(),
        a.values().iter().map(|v| 3.0 * v - 1.0).collect(),
    );
    assert_eq!(a.pattern_fingerprint(), scaled.pattern_fingerprint());
    let key = PlanKey::of(&machine, &a, &b, 1, Partition::Flops);
    assert_eq!(key, PlanKey::of(&machine, &scaled, &b, 1, Partition::Flops));
    let mut ws = Workspace::new();
    let plan = SpmmmPlan::build(&machine, &a, &b, key, &mut ws);
    let mut out = CsrMatrix::new(0, 0);
    planned_fill_serial(&plan, &scaled, &b, &mut ws.plan_temp, &mut out);
    let reference = spmmm(&scaled, &b, Strategy::Combined);
    assert!(out.approx_eq(&reference, 0.0), "same plan, new values");
}

/// Bitwise (not just numeric) equality of two CSR results — the only
/// comparison that distinguishes `0.0` from `-0.0` and sees NaN as
/// equal to itself, which is what the special-values identity below
/// needs.
fn assert_csr_bits_eq(got: &CsrMatrix, want: &CsrMatrix, ctx: &str) {
    assert_eq!(got.row_ptr(), want.row_ptr(), "{ctx}: row_ptr");
    assert_eq!(got.col_idx(), want.col_idx(), "{ctx}: col_idx");
    let gb: Vec<u64> = got.values().iter().map(|v| v.to_bits()).collect();
    let wb: Vec<u64> = want.values().iter().map(|v| v.to_bits()).collect();
    assert_eq!(gb, wb, "{ctx}: value bits");
}

fn assert_csc_bits_eq(got: &CscMatrix, want: &CscMatrix, ctx: &str) {
    assert_eq!(got.col_ptr(), want.col_ptr(), "{ctx}: col_ptr");
    assert_eq!(got.row_idx(), want.row_idx(), "{ctx}: row_idx");
    let gb: Vec<u64> = got.values().iter().map(|v| v.to_bits()).collect();
    let wb: Vec<u64> = want.values().iter().map(|v| v.to_bits()).collect();
    assert_eq!(gb, wb, "{ctx}: value bits");
}

/// The `simd` build's unrolled lanes must be *bitwise* indistinguishable
/// from the scalar build — both are pinned against the same scalar
/// reference here (run the suite with and without `--features simd`;
/// each build matching the reference bit-for-bit makes the two builds
/// bit-identical to each other). The operands are built to exercise
/// every special value the drop rule (`v != 0.0`) has an opinion on:
///
/// * exact cancellation (`+1.5 + -1.5` → `+0.0`, dropped) — and each
///   cancelled position receives exactly two contributions whose sum is
///   `+0.0` in either order, so the check is accumulation-order-proof;
/// * negative zero produced by `-1.0 × 0.0` (dropped: `-0.0 != 0.0` is
///   false);
/// * NaN produced by `c × NaN` (kept: NaN `!= 0.0` is true) — one
///   contribution per output slot, so the bit pattern is whatever the
///   one multiply produced on this hardware, identically in every
///   kernel;
/// * empty rows in both operands (empty-row slabs on every partition).
#[test]
fn special_values_bit_identical_across_strategies_partitions_threads() {
    let machine = Machine::sandy_bridge_i7_2600();
    let pool = ExecPool::new(3);
    let mut b = CsrMatrix::new(4, 8);
    for c in [0usize, 2, 5] {
        b.append(c, 1.5);
    }
    b.finalize_row();
    for c in [0usize, 2, 5] {
        b.append(c, 1.5);
    }
    b.finalize_row();
    b.append(1, 0.0);
    b.append(3, f64::NAN);
    b.finalize_row();
    b.finalize_row(); // row 3 empty
    let mut a = CsrMatrix::new(6, 4);
    a.append(0, 1.0);
    a.append(1, -1.0); // row 0: exact cancellation against b's twin rows
    a.finalize_row();
    a.finalize_row(); // row 1 empty
    a.append(2, -1.0); // row 2: -1·0.0 = -0.0 (drop), -1·NaN = NaN (keep)
    a.finalize_row();
    a.append(3, 2.0); // row 3: hits only b's empty row
    a.finalize_row();
    a.append(0, 1.0);
    a.append(2, 3.0); // row 4: disjoint contributions, incl. 3·NaN
    a.finalize_row();
    a.finalize_row(); // row 5 empty

    let reference = spmmm(&a, &b, Strategy::Combined);
    assert_eq!(reference.row_nnz(0), 0, "cancelled row compacts away");
    assert_eq!(reference.row_nnz(2), 1, "-0.0 dropped, NaN kept");
    assert!(reference.values()[reference.row_ptr()[2]].is_nan());
    assert_eq!(reference.row_nnz(3), 0, "empty B row yields an empty row");

    for strategy in Strategy::ALL {
        let c = spmmm(&a, &b, strategy);
        assert_csr_bits_eq(&c, &reference, strategy.name());
    }
    let mut ws = Workspace::new();
    let mut out = CsrMatrix::new(0, 0);
    for partition in Partition::ALL {
        for threads in [1usize, 2, 4, 8] {
            let ctx = format!("planned {partition:?} threads={threads}");
            let key = PlanKey::of(&machine, &a, &b, threads, partition);
            let plan = SpmmmPlan::build(&machine, &a, &b, key, &mut ws);
            if threads > 1 {
                par_planned_fill(&pool, &plan, &a, &b, &mut out);
            } else {
                planned_fill_serial(&plan, &a, &b, &mut ws.plan_temp, &mut out);
            }
            assert_csr_bits_eq(&out, &reference, &ctx);
            for strategy in Strategy::ALL {
                par_spmmm_into(
                    &pool, &a, &b, threads, strategy, partition, &machine, &mut out,
                );
                assert_csr_bits_eq(
                    &out,
                    &reference,
                    &format!("{} {partition:?} threads={threads}", strategy.name()),
                );
            }
        }
    }
    // The same special values through the column-major planned path.
    let (ac, bc) = (csr_to_csc(&a), csr_to_csc(&b));
    let csc_reference = spmmm_csc(&ac, &bc, Strategy::Combined);
    let mut out_csc = CscMatrix::new(0, 0);
    for threads in [1usize, 4] {
        let key = PlanKey::of_csc(&machine, &ac, &bc, threads, Partition::Flops);
        let plan = SpmmmPlan::build_csc(&machine, &ac, &bc, key, &mut ws);
        planned_fill_serial_csc(&plan, &ac, &bc, &mut ws.plan_temp, &mut out_csc);
        assert_csc_bits_eq(&out_csc, &csc_reference, &format!("csc threads={threads}"));
    }
}

/// Warm CSC products ride the plan cache exactly like CSR products:
/// one symbolic build on first sight, every repeat a hit, and the
/// planned refill bit-identical to the unplanned column kernel. The
/// mixed CSR·CSC product keys separately (its fingerprints are
/// order-tagged) and adds its own single build.
#[test]
fn warm_csc_products_hit_the_plan_cache() {
    let machine = Machine::sandy_bridge_i7_2600();
    let cache = PlanCache::default();
    let mut ws = Workspace::new();
    let (a_csr, b_csr) = operand_pair(Workload::FiveBandFd, 180, 11);
    let (a, b) = (csr_to_csc(&a_csr), csr_to_csc(&b_csr));
    let reference = spmmm_csc(&a, &b, Strategy::Combined);
    let mut out = CscMatrix::new(0, 0);
    for rep in 0..3 {
        let plan = cache.get_or_build_csc(&machine, &mut ws, &a, &b, 1, Partition::Flops);
        planned_fill_serial_csc(&plan, &a, &b, &mut ws.plan_temp, &mut out);
        assert_csc_bits_eq(&out, &reference, &format!("rep={rep}"));
    }
    let s = cache.stats();
    assert_eq!(s.symbolic_builds, 1, "one symbolic phase for three evaluations");
    assert!(s.hits >= 2, "every repeat is a hit (got {})", s.hits);

    let mixed_reference = spmmm_csr_csc(&a_csr, &b, Strategy::Combined);
    let mut out_csr = CsrMatrix::new(0, 0);
    for _ in 0..2 {
        let plan = cache.get_or_build_csr_csc(&machine, &mut ws, &a_csr, &b, 1, Partition::Flops);
        planned_fill_csr_csc(&plan, &a_csr, &b, &mut ws.plan_temp, &mut out_csr);
        assert_csr_bits_eq(&out_csr, &mixed_reference, "mixed csr·csc");
    }
    let s = cache.stats();
    assert_eq!(s.symbolic_builds, 2, "the mixed product keys and builds separately");
    assert!(s.hits >= 3);
}

/// Release-smoke contract: a *restarted* session (fresh cache, same
/// store directory — by now compacted into a single segment) warm-starts
/// the CSC plan from disk and reports **zero** symbolic builds.
#[test]
fn warm_csc_restart_runs_zero_symbolic_builds() {
    let machine = Machine::sandy_bridge_i7_2600();
    let dir = std::env::temp_dir().join(format!(
        "blazert_itest_csc_restart_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let (a_csr, b_csr) = operand_pair(Workload::FiveBandFd, 160, 3);
    let (a, b) = (csr_to_csc(&a_csr), csr_to_csc(&b_csr));
    let reference = spmmm_csc(&a, &b, Strategy::Combined);
    let mut ws = Workspace::new();
    {
        let cache = PlanCache::default();
        let plan = cache.get_or_build_csc(&machine, &mut ws, &a, &b, 1, Partition::Flops);
        let mut out = CscMatrix::new(0, 0);
        planned_fill_serial_csc(&plan, &a, &b, &mut ws.plan_temp, &mut out);
        assert_csc_bits_eq(&out, &reference, "first session");
        let store = PlanStore::open_default(&dir).expect("store opens");
        assert_eq!(cache.persist_to_dir(&store), 1);
    }
    // Simulated restart: everything in-memory is gone, only the (now
    // segment-compacted) directory survives.
    let store = Arc::new(PlanStore::open_default(&dir).expect("store reopens"));
    let cache = PlanCache::default();
    cache.attach_store(store);
    let mut out = CscMatrix::new(0, 0);
    for _ in 0..3 {
        let plan = cache.get_or_build_csc(&machine, &mut ws, &a, &b, 1, Partition::Flops);
        planned_fill_serial_csc(&plan, &a, &b, &mut ws.plan_temp, &mut out);
        assert_csc_bits_eq(&out, &reference, "restarted session");
    }
    let s = cache.stats();
    assert_eq!(s.symbolic_builds, 0, "warm restart must not re-run the symbolic phase");
    assert_eq!(s.disk_loads, 1, "the plan came off disk exactly once");
    assert_eq!(s.hits, 3, "every warm evaluation counts as a hit");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn csc_leaf_assignment_reuses_buffers() {
    use blazert::sparse::convert::csr_to_csc;
    let (a, _) = operand_pair(Workload::RandomFixed5, 60, 33);
    let a_csc = csr_to_csc(&a);
    let mut out = CsrMatrix::new(0, 0);
    let mut ctx = EvalContext::new();
    a_csc.assign_to(&mut out, &mut ctx);
    assert!(out.approx_eq(&a, 0.0), "CSC leaf converts to the CSR value");
    let cap = out.capacity();
    a_csc.assign_to(&mut out, &mut ctx);
    assert!(out.approx_eq(&a, 0.0));
    assert_eq!(out.capacity(), cap, "leaf conversion reuses the buffers");
}
