//! Integration tests for the persistent execution engine: bit-identity
//! of the size-then-fill parallel kernel against the serial kernel for
//! every storing strategy, partition, and thread count — including
//! empty slabs, a single hot row, and threads > rows — plus
//! pool/workspace reuse across calls, expression-layer integration, and
//! the symbolic/numeric plan split (planned evaluation bit-identical to
//! unplanned everywhere; cache hits perform no symbolic work).

use blazert::exec::{ExecPool, Partition, Workspace};
use blazert::expr::{EvalContext, Expression, SparseOperand};
use blazert::gen::{operand_pair, random_power_law, Workload};
use blazert::kernels::parallel::{par_planned_fill, par_spmmm, par_spmmm_into, par_spmmm_with};
use blazert::kernels::{planned_fill_serial, spmmm, Strategy};
use blazert::model::Machine;
use blazert::plan::{PlanCache, PlanKey, SpmmmPlan};
use blazert::sparse::{CsrMatrix, SparseShape};

#[test]
fn bit_identity_all_strategies_partitions_threads() {
    let pool = ExecPool::new(3);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut out = CsrMatrix::new(0, 0);
    for workload in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::PowerLawSkew] {
        let (a, b) = operand_pair(workload, 240, 17);
        for strategy in Strategy::ALL {
            let serial = spmmm(&a, &b, strategy);
            for partition in Partition::ALL {
                for threads in [1usize, 2, 5, 16] {
                    par_spmmm_into(
                        &pool, &a, &b, threads, strategy, partition, &machine, &mut out,
                    );
                    assert!(
                        out.approx_eq(&serial, 0.0),
                        "{workload:?} {} {partition:?} threads={threads}",
                        strategy.name()
                    );
                }
            }
        }
    }
}

#[test]
fn single_hot_row_and_empty_slabs() {
    // Row 0 holds every column; all other rows are empty — the
    // flop-balanced cut assigns the hot row one slab and leaves later
    // slabs empty, which must still produce a bit-identical result.
    let n = 64usize;
    let mut a = CsrMatrix::new(n, n);
    for c in 0..n {
        a.append(c, (c + 1) as f64);
    }
    a.finalize_row();
    for _ in 1..n {
        a.finalize_row();
    }
    let b = random_power_law(n, n, 16, 1.0, 3);
    for strategy in [Strategy::MinMax, Strategy::Sort, Strategy::Combined] {
        let serial = spmmm(&a, &b, strategy);
        for threads in [2usize, 8, n, 4 * n] {
            let par = par_spmmm_with(&a, &b, threads, strategy);
            assert!(par.approx_eq(&serial, 0.0), "{} threads={threads}", strategy.name());
        }
    }
}

#[test]
fn threads_exceed_rows_on_tiny_matrices() {
    for rows in [1usize, 2, 3] {
        let (a, b) = operand_pair(Workload::RandomFixed5, rows, 9);
        let serial = spmmm(&a, &b, Strategy::Combined);
        let par = par_spmmm(&a, &b, 64);
        assert!(par.approx_eq(&serial, 0.0), "rows={rows}");
    }
}

#[test]
fn empty_operands_all_partitions() {
    let pool = ExecPool::new(2);
    let machine = Machine::sandy_bridge_i7_2600();
    let z = CsrMatrix::from_parts(7, 7, vec![0; 8], vec![], vec![]);
    let mut out = CsrMatrix::new(0, 0);
    for partition in Partition::ALL {
        par_spmmm_into(&pool, &z, &z, 4, Strategy::Combined, partition, &machine, &mut out);
        assert_eq!(out.nnz(), 0, "{partition:?}");
        assert!(out.is_finalized());
        assert_eq!(out.rows(), 7);
    }
}

#[test]
fn pool_is_reused_across_many_calls_and_sizes() {
    // One pool, many products of varying shape: workspaces grow
    // monotonically and results stay exact throughout.
    let pool = ExecPool::new(2);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut out = CsrMatrix::new(0, 0);
    for n in [30usize, 120, 60, 200, 40] {
        let (a, b) = operand_pair(Workload::RandomFixed5, n, n as u64);
        let serial = spmmm(&a, &b, Strategy::Combined);
        par_spmmm_into(
            &pool,
            &a,
            &b,
            2,
            Strategy::Combined,
            Partition::Flops,
            &machine,
            &mut out,
        );
        assert!(out.approx_eq(&serial, 0.0), "n={n}");
    }
}

#[test]
fn expression_trees_evaluate_through_the_pool() {
    let pool = ExecPool::new(2);
    let (a, b) = operand_pair(Workload::RandomFixed5, 80, 21);
    let c = b.clone();
    let reference = {
        let ab = spmmm(&a, &b, Strategy::Combined);
        spmmm(&ab, &c, Strategy::Combined)
    };
    // Chained product through a pooled parallel context.
    let mut ctx = EvalContext::new().with_exec(&pool).with_threads(2);
    let got = (&a * &b * &c).eval_with(&mut ctx);
    assert!(got.approx_eq(&reference, 0.0));
    // Pooled assign_to reuses the output and stays exact on repeat.
    let mut out = CsrMatrix::new(0, 0);
    let prod = &a * &b;
    prod.assign_to(&mut out, &mut ctx);
    let cap = out.capacity();
    prod.assign_to(&mut out, &mut ctx);
    assert_eq!(out.capacity(), cap, "warm assignment allocates nothing");
    assert!(out.approx_eq(&spmmm(&a, &b, Strategy::Combined), 0.0));
}

/// Property: planned evaluation is bit-identical to every unplanned
/// strategy, for every partition and thread count, on every workload —
/// the planned numeric phase must be indistinguishable from the kernels
/// it replaces.
#[test]
fn planned_bit_identical_across_strategies_partitions_threads() {
    let pool = ExecPool::new(3);
    let machine = Machine::sandy_bridge_i7_2600();
    let mut ws = Workspace::new();
    let mut temp = Vec::new();
    let mut out = CsrMatrix::new(0, 0);
    for workload in [Workload::FiveBandFd, Workload::RandomFixed5, Workload::PowerLawSkew] {
        let (a, b) = operand_pair(workload, 240, 17);
        // Every unplanned strategy agrees with the reference bit-exactly…
        let reference = spmmm(&a, &b, Strategy::Combined);
        for strategy in Strategy::ALL {
            let c = spmmm(&a, &b, strategy);
            assert!(c.approx_eq(&reference, 0.0), "{workload:?} {}", strategy.name());
        }
        // …so one planned-vs-reference check per (partition, threads)
        // covers planned-vs-every-strategy.
        for partition in Partition::ALL {
            for threads in [1usize, 2, 5, 16] {
                let key = PlanKey::of(&machine, &a, &b, threads, partition);
                let plan = SpmmmPlan::build(&machine, &a, &b, key, &mut ws);
                if threads > 1 {
                    par_planned_fill(&pool, &plan, &a, &b, &mut out);
                } else {
                    planned_fill_serial(&plan, &a, &b, &mut temp, &mut out);
                }
                assert!(
                    out.approx_eq(&reference, 0.0),
                    "{workload:?} {partition:?} threads={threads}"
                );
            }
        }
    }
}

/// Exact cancellation and empty rows: the structural pattern keeps the
/// cancelled positions, the numeric compaction must drop them — on the
/// serial and the parallel planned path alike.
#[test]
fn planned_cancellation_and_empty_rows() {
    let machine = Machine::sandy_bridge_i7_2600();
    let pool = ExecPool::new(2);
    // B has two identical rows; row 0 of A multiplies them with opposite
    // signs (exact cancellation), rows 1/3 are empty.
    let mut b = CsrMatrix::new(2, 8);
    for c in [0usize, 2, 5] {
        b.append(c, 1.5);
    }
    b.finalize_row();
    for c in [0usize, 2, 5] {
        b.append(c, 1.5);
    }
    b.finalize_row();
    let mut a = CsrMatrix::new(4, 2);
    a.append(0, 1.0);
    a.append(1, -1.0);
    a.finalize_row();
    a.finalize_row();
    a.append(1, 2.0);
    a.finalize_row();
    a.finalize_row();
    let reference = spmmm(&a, &b, Strategy::Combined);
    assert_eq!(reference.row_nnz(0), 0, "row 0 cancels exactly");
    assert_eq!(reference.row_nnz(2), 3);
    let mut ws = Workspace::new();
    let mut out = CsrMatrix::new(0, 0);
    for partition in Partition::ALL {
        for threads in [1usize, 2, 4] {
            let key = PlanKey::of(&machine, &a, &b, threads, partition);
            let plan = SpmmmPlan::build(&machine, &a, &b, key, &mut ws);
            assert_eq!(plan.pattern_nnz(), 6, "pattern is cancellation-blind");
            if threads > 1 {
                par_planned_fill(&pool, &plan, &a, &b, &mut out);
            } else {
                planned_fill_serial(&plan, &a, &b, &mut ws.plan_temp, &mut out);
            }
            assert!(out.approx_eq(&reference, 0.0), "{partition:?} threads={threads}");
            assert_eq!(out.nnz(), 3, "cancelled slack compacted away");
        }
    }
}

/// The headline counter proof: once a plan is cached, re-evaluating the
/// expression performs **no symbolic phase** — `symbolic_builds` stays
/// flat while `hits` counts every warm evaluation.
#[test]
fn plan_cache_hits_skip_the_symbolic_phase() {
    let pool = ExecPool::new(2);
    let cache = PlanCache::default();
    let (a, b) = operand_pair(Workload::FiveBandFd, 240, 5);
    let reference = spmmm(&a, &b, Strategy::Combined);
    let mut out = CsrMatrix::new(0, 0);
    for threads in [1usize, 2] {
        let mut ctx = EvalContext::new()
            .with_exec(&pool)
            .with_threads(threads)
            .with_plan_cache(&cache);
        let prod = &a * &b;
        // Unplanned first sight, then one symbolic build on repeat.
        prod.assign_to(&mut out, &mut ctx);
        prod.assign_to(&mut out, &mut ctx);
        let builds = cache.stats().symbolic_builds;
        let hits = cache.stats().hits;
        for i in 0..4 {
            prod.assign_to(&mut out, &mut ctx);
            assert!(out.approx_eq(&reference, 0.0), "threads={threads} rep={i}");
        }
        let stats = cache.stats();
        assert_eq!(stats.symbolic_builds, builds, "cache hits must not re-run symbolic");
        assert_eq!(stats.hits, hits + 4, "every warm evaluation is a hit");
    }
}

/// Values may change freely under a fixed pattern: the fingerprint (and
/// the cached plan) only track structure, and the refill picks up the
/// new values — the iterative-scheme contract.
#[test]
fn plan_survives_value_changes_under_fixed_pattern() {
    let machine = Machine::sandy_bridge_i7_2600();
    let (a, b) = operand_pair(Workload::RandomFixed5, 120, 23);
    let scaled = CsrMatrix::from_parts(
        a.rows(),
        a.cols(),
        a.row_ptr().to_vec(),
        a.col_idx().to_vec(),
        a.values().iter().map(|v| 3.0 * v - 1.0).collect(),
    );
    assert_eq!(a.pattern_fingerprint(), scaled.pattern_fingerprint());
    let key = PlanKey::of(&machine, &a, &b, 1, Partition::Flops);
    assert_eq!(key, PlanKey::of(&machine, &scaled, &b, 1, Partition::Flops));
    let mut ws = Workspace::new();
    let plan = SpmmmPlan::build(&machine, &a, &b, key, &mut ws);
    let mut out = CsrMatrix::new(0, 0);
    planned_fill_serial(&plan, &scaled, &b, &mut ws.plan_temp, &mut out);
    let reference = spmmm(&scaled, &b, Strategy::Combined);
    assert!(out.approx_eq(&reference, 0.0), "same plan, new values");
}

#[test]
fn csc_leaf_assignment_reuses_buffers() {
    use blazert::sparse::convert::csr_to_csc;
    let (a, _) = operand_pair(Workload::RandomFixed5, 60, 33);
    let a_csc = csr_to_csc(&a);
    let mut out = CsrMatrix::new(0, 0);
    let mut ctx = EvalContext::new();
    a_csc.assign_to(&mut out, &mut ctx);
    assert!(out.approx_eq(&a, 0.0), "CSC leaf converts to the CSR value");
    let cap = out.capacity();
    a_csc.assign_to(&mut out, &mut ctx);
    assert!(out.approx_eq(&a, 0.0));
    assert_eq!(out.capacity(), cap, "leaf conversion reuses the buffers");
}
