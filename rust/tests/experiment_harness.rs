//! End-to-end pins of the declarative experiment harness: definition →
//! run → JSON render → reload round-trip, noise-band edge cases at the
//! public gate API, and the committed `experiments/` + `baselines/`
//! artifacts staying well-formed.

use blazert::blazemark::{row_field, BenchRecord};
use blazert::harness::{
    compare, find_repo_file, run_experiment, ExperimentDef, MetricPolicy, RunOptions,
};
use blazert::util::json::Json;

/// Small enough to run in test time, wide enough to cover the strategy
/// split, replicate aggregation, and the warm symbolic counter.
const TINY: &str = r#"
schema = "blazert-experiment-v1"
name = "tiny"
hypothesis = "round-trips survive the disk format"

[protocol]
quick_min_time_s = 0.001
quick_trials = 1
quick_replicates = 2

[[workloads]]
generator = "FD"
n = 144
seed = 3

[variants]
plan_modes = ["unplanned", "warm"]
threads = [1, 2]

[[metrics]]
name = "symbolic_builds"
band = 0.0
gate = true

[[metrics]]
name = "mflops"
band = 0.10
"#;

#[test]
fn run_record_round_trips_and_gates_itself() {
    let def = ExperimentDef::parse(TINY).unwrap();
    let rec = run_experiment(&def, &RunOptions::default()).unwrap();
    assert_eq!(rec.rows.len(), 4, "2 plan modes × 2 thread counts, replicates collapsed");

    // Disk round-trip: render → parse reproduces the record exactly.
    let again = BenchRecord::parse(&rec.render()).unwrap();
    assert_eq!(rec, again);

    // A run gates cleanly against itself (warm rows carry the symbolic
    // counter; identical values sit inside every band).
    let rep = compare(&again, &rec, &def.metrics);
    assert!(rep.passed(), "{}", rep.render());
    assert_eq!(rep.checked, 2, "symbolic_builds gated on the two warm rows");
    assert!(rep.new_rows.is_empty());

    // Injected regression: bump the gated counter on every row that
    // carries it — the gate must fail (the CI self-test contract).
    let mut bad = rec.clone();
    let mut touched = 0;
    for row in &mut bad.rows {
        for (name, v) in row.iter_mut() {
            if name == "symbolic_builds" {
                *v = Json::Num(7.0);
                touched += 1;
            }
        }
    }
    assert_eq!(touched, 2);
    let rep = compare(&rec, &bad, &def.metrics);
    assert!(!rep.passed());
    assert_eq!(rep.regressions.len(), 2, "{}", rep.render());

    // A gated metric silently vanishing from the run is a failure too.
    let mut base = rec.clone();
    base.rows[0].push(("steady_allocs".into(), Json::Num(0.0)));
    let policies =
        vec![MetricPolicy { name: "steady_allocs".into(), band: 0.0, gate: true }];
    let rep = compare(&base, &rec, &policies);
    assert!(!rep.passed(), "{}", rep.render());
    assert!(rep.regressions[0].detail.contains("missing"), "{}", rep.render());
}

fn record_with_mflops(mflops: f64) -> BenchRecord {
    let mut rec = BenchRecord::new("edges");
    rec.rows = vec![vec![
        ("workload".into(), Json::Str("FD".into())),
        ("threads".into(), Json::Num(1.0)),
        ("mflops".into(), Json::Num(mflops)),
    ]];
    rec
}

#[test]
fn band_edges_and_new_rows_at_the_gate_level() {
    let base = record_with_mflops(1000.0);
    let gate = vec![MetricPolicy { name: "mflops".into(), band: 0.10, gate: true }];

    // Exactly at the band edge passes; one tick below fails.
    assert!(compare(&base, &record_with_mflops(900.0), &gate).passed());
    assert!(!compare(&base, &record_with_mflops(899.9), &gate).passed());
    // Improvements always pass a higher-is-better gate.
    assert!(compare(&base, &record_with_mflops(5000.0), &gate).passed());

    // Rows the baseline does not know about are reported, not failed.
    let mut run = record_with_mflops(1000.0);
    run.rows.push(vec![
        ("workload".into(), Json::Str("power-law".into())),
        ("threads".into(), Json::Num(8.0)),
        ("mflops".into(), Json::Num(50.0)),
    ]);
    let rep = compare(&base, &run, &gate);
    assert!(rep.passed());
    assert_eq!(rep.new_rows.len(), 1);
    assert!(rep.new_rows[0].contains("workload=power-law"), "{:?}", rep.new_rows);
}

#[test]
fn committed_definitions_and_baselines_stay_well_formed() {
    // Every committed definition parses, and its variant matrix has the
    // shape the baselines and snapshots were written for.
    for (name, points) in [
        ("plan_ablation", 8),
        ("simd_ablation", 4),
        ("threads_ablation", 12),
        ("scenario_corpus", 4),
        ("chain_fusion_ablation", 4),
        // A `[service]` definition bypasses the variant sweep; its
        // defaulted matrix is the single trivial point.
        ("service_saturation", 1),
    ] {
        let path = find_repo_file(&format!("experiments/{name}.toml"));
        let def = ExperimentDef::load(&path).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(def.name, name);
        assert_eq!(def.variants.points().len(), points, "{name} matrix shape");
        assert!(def.hypothesis.is_some(), "{name} declares a hypothesis");
    }
    // The structured-operand corpus exercises the banded and
    // block-structured generators through the harness.
    let corpus =
        ExperimentDef::load(&find_repo_file("experiments/scenario_corpus.toml")).unwrap();
    let tags: Vec<&str> = corpus.workloads.iter().map(|w| w.generator.tag()).collect();
    assert_eq!(tags, vec!["banded", "block"]);

    // Committed baselines parse under the unified record schema and
    // only pin invariant counters (never machine-dependent perf).
    for name in [
        "plan_ablation",
        "simd_ablation",
        "fusion_ablation",
        "chain_fusion_ablation",
        "service_saturation",
    ] {
        let path = find_repo_file(&format!("baselines/experiments/{name}.json"));
        let base = BenchRecord::load(&path).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(base.bench, name);
        assert!(!base.rows.is_empty());
        for row in &base.rows {
            assert!(row_field(row, "mflops").is_none(), "{name} baseline gates perf");
            for metric in [
                "symbolic_builds",
                "steady_allocs",
                "intermediate_allocs",
                "lost_jobs",
                "duplicate_jobs",
                "rejected_jobs",
            ] {
                if let Some(v) = row_field(row, metric) {
                    assert_eq!(v.as_f64(), Some(0.0), "{name}: {metric} is an invariant");
                }
            }
        }
    }
    // The regenerated trajectory snapshots are readable by the same
    // schema (so `experiment print`/`compare` can consume them).
    for file in ["BENCH_plan.json", "BENCH_simd.json"] {
        let rec = BenchRecord::load(&find_repo_file(file)).unwrap_or_else(|e| panic!("{e}"));
        assert!(rec.rows.len() >= 8, "{file}");
        assert!(rec.hypothesis.is_some(), "{file}");
    }
}
