//! The plan store's headline guarantee, end to end: a plan persisted by
//! process A and loaded by process B (simulated here as two caches over
//! one directory) refills **bit-identically** to the unplanned kernels
//! across storing strategies × partitions × thread counts, and the
//! restarted cache's counters prove the warm path ran **zero symbolic
//! builds** — the "restart without re-warming" contract.

use std::sync::Arc;

use blazert::exec::{default_machine, ExecPool, Partition, Workspace};
use blazert::expr::EvalContext;
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::{spmmm, Strategy};
use blazert::plan::{PlanCache, PlanStore};
use blazert::sparse::CsrMatrix;

const THREADS: [usize; 2] = [1, 2];

fn store_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("blazert_rt_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn persisted_plans_refill_bit_identically_after_restart() {
    let operands: Vec<(CsrMatrix, CsrMatrix)> = vec![
        operand_pair(Workload::FiveBandFd, 150, 9),
        operand_pair(Workload::RandomFixed5, 120, 5),
    ];
    let dir = store_dir("bitident");
    let shapes: Vec<(usize, Partition)> = THREADS
        .iter()
        .flat_map(|&t| Partition::ALL.iter().map(move |&p| (t, p)))
        .collect();

    // --- "Process A": build every plan through a write-through store. ---
    let saved = {
        let store = Arc::new(PlanStore::open_default(&dir).expect("store opens"));
        let cache = PlanCache::default();
        cache.attach_store(Arc::clone(&store));
        let mut ws = Workspace::new();
        for (a, b) in &operands {
            for &(threads, partition) in &shapes {
                cache.get_or_build(default_machine(), &mut ws, a, b, threads, partition);
            }
        }
        let expected = operands.len() * shapes.len();
        assert_eq!(cache.stats().symbolic_builds as usize, expected);
        assert_eq!(store.len(), expected, "every plan persisted");
        expected
    };

    // --- "Process B": a fresh cache over the same directory. ---
    let store = Arc::new(PlanStore::open_default(&dir).expect("store reopens"));
    let cache = PlanCache::default();
    assert_eq!(cache.warm_from_dir(&store), saved, "warm start recovers every plan");

    let pool = ExecPool::new(2);
    let mut out = CsrMatrix::new(0, 0);
    let mut planned_evals = 0u64;
    for (a, b) in &operands {
        for &(threads, partition) in &shapes {
            let mut ctx = EvalContext::new()
                .with_exec(&pool)
                .with_threads(threads)
                .with_partition(partition)
                .with_plan_cache(&cache);
            ctx.product_into(a, b, &mut out);
            planned_evals += 1;
            // Bit-identical to the unplanned kernel under *every*
            // storing strategy (they are bit-identical by construction,
            // so this also cross-checks the planned refill against each).
            for strategy in Strategy::ALL {
                let reference = spmmm(a, b, strategy);
                assert!(
                    out.approx_eq(&reference, 0.0),
                    "threads={threads} partition={partition:?} vs {}",
                    strategy.name()
                );
            }
        }
    }

    // The warm path ran no symbolic phase at all — every evaluation was
    // a cache hit backed by a disk recovery.
    let s = cache.stats();
    assert_eq!(s.symbolic_builds, 0, "zero symbolic builds on the warm path");
    assert_eq!(s.misses, 0, "every probe hit");
    assert_eq!(s.hits, planned_evals);
    assert_eq!(s.disk_loads as usize, saved);
    assert_eq!(store.stats().store_rejected, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lazy_load_on_miss_also_restarts_symbolic_free() {
    // Same contract without the eager scan: attach the store but let
    // every plan be recovered lazily by the first probe of its key.
    let (a, b) = operand_pair(Workload::FiveBandFd, 130, 11);
    let dir = store_dir("lazy");
    {
        let store = Arc::new(PlanStore::open_default(&dir).expect("store opens"));
        let cache = PlanCache::default();
        cache.attach_store(Arc::clone(&store));
        let mut ws = Workspace::new();
        for &threads in &THREADS {
            cache.get_or_build(default_machine(), &mut ws, &a, &b, threads, Partition::Flops);
        }
    }
    let store = Arc::new(PlanStore::open_default(&dir).expect("store reopens"));
    let cache = PlanCache::default();
    cache.attach_store(Arc::clone(&store));
    let reference = spmmm(&a, &b, Strategy::Combined);
    let mut out = CsrMatrix::new(0, 0);
    for &threads in &THREADS {
        let mut ctx = EvalContext::new().with_threads(threads).with_plan_cache(&cache);
        for _ in 0..3 {
            ctx.product_into(&a, &b, &mut out);
            assert!(out.approx_eq(&reference, 0.0), "threads={threads}");
        }
    }
    let s = cache.stats();
    assert_eq!(s.symbolic_builds, 0, "lazy recovery needs no symbolic work");
    assert_eq!(s.disk_loads, 2, "one disk recovery per evaluation shape");
    assert_eq!(s.hits, 6, "every later probe is a pure memory hit");
    std::fs::remove_dir_all(&dir).ok();
}
