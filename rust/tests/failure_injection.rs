//! Failure injection: corrupt inputs, bad geometry, contract violations.
//! The library must fail loudly and precisely, not corrupt results.

use blazert::gen::random_fixed_per_row;
use blazert::kernels::{spmmm, Strategy};
use blazert::runtime::Manifest;
use blazert::simulator::{Cache, CacheConfig};
use blazert::sparse::{CooMatrix, CsrMatrix};
use std::path::Path;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("blazert_fi_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn spmmm_rejects_dimension_mismatch() {
    let a = random_fixed_per_row(10, 20, 3, 1);
    let b = random_fixed_per_row(21, 10, 3, 2); // 20 != 21
    let r = std::panic::catch_unwind(|| spmmm(&a, &b, Strategy::Combined));
    assert!(r.is_err(), "mismatched inner dimension must panic");
}

#[test]
fn from_parts_rejects_corrupt_structures() {
    // Out-of-bounds column index.
    let r = std::panic::catch_unwind(|| {
        CsrMatrix::from_parts(1, 2, vec![0, 1], vec![5], vec![1.0])
    });
    assert!(r.is_err());
    // Non-monotone row_ptr.
    let r = std::panic::catch_unwind(|| {
        CsrMatrix::from_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 2.0])
    });
    assert!(r.is_err());
    // Duplicate column within a row.
    let r = std::panic::catch_unwind(|| {
        CsrMatrix::from_parts(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 2.0])
    });
    assert!(r.is_err());
}

#[test]
fn coo_rejects_out_of_bounds() {
    let mut m = CooMatrix::new(3, 3);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| m.push(0, 3, 1.0)));
    assert!(r.is_err());
}

#[test]
fn manifest_corruption_modes() {
    // Missing directory.
    assert!(Manifest::load(Path::new("/definitely/not/here")).is_err());

    // Garbled field.
    let d = tmpdir("garbled");
    std::fs::write(d.join("manifest.txt"), "name=x file\n").unwrap();
    assert!(Manifest::load(&d).is_err());

    // Non-numeric shape.
    let d2 = tmpdir("shape");
    std::fs::write(d2.join("manifest.txt"), "name=x file=x.hlo dtype=f32 args=axb\n").unwrap();
    assert!(Manifest::load(&d2).is_err());

    // Missing required key.
    let d3 = tmpdir("missing");
    std::fs::write(d3.join("manifest.txt"), "file=x.hlo dtype=f32 args=2x2\n").unwrap();
    assert!(Manifest::load(&d3).is_err());

    for d in [d, d2, d3] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn runtime_rejects_corrupt_hlo() {
    if !blazert::runtime::Runtime::artifacts_available() {
        eprintln!("[runtime_rejects_corrupt_hlo] no artifacts; skipping");
        return;
    }
    // Copy the real manifest but point an entry at corrupt HLO text.
    let d = tmpdir("badhlo");
    std::fs::write(
        d.join("manifest.txt"),
        "name=tile_mma file=bad.hlo.txt dtype=f32 args=64x32x32,64x32x32,64x32x32 tile=32 batch=64 groups=16 group_k=8 dense_n=256\n",
    )
    .unwrap();
    std::fs::write(d.join("bad.hlo.txt"), "HloModule garbage\nENTRY oops { broken }\n").unwrap();
    let rt = blazert::runtime::Runtime::load(&d);
    // Loading the manifest succeeds; compilation of the bad entry fails.
    let mut rt = rt.expect("manifest itself parses");
    let te = 64 * 32 * 32;
    let z = vec![0f32; te];
    let shape = [64usize, 32, 32];
    let err = rt.execute_f32("tile_mma", &[(&z, &shape), (&z, &shape), (&z, &shape)]);
    assert!(err.is_err(), "corrupt HLO must fail compilation");
    std::fs::remove_dir_all(d).ok();
}

#[test]
fn cache_config_validation() {
    // Non-power-of-two line size.
    let r = std::panic::catch_unwind(|| {
        Cache::new(CacheConfig { name: "X", size_bytes: 512, line_bytes: 48, assoc: 2 })
    });
    assert!(r.is_err());
    // Zero sets (assoc too large).
    let r = std::panic::catch_unwind(|| {
        Cache::new(CacheConfig { name: "X", size_bytes: 64, line_bytes: 64, assoc: 2 })
    });
    assert!(r.is_err());
}

#[test]
fn bsr_backend_tile_mismatch_is_checked() {
    use blazert::bsr::{bsr_spmmm, BsrMatrix, NativeBackend};
    let a = random_fixed_per_row(16, 16, 3, 1);
    let ab = BsrMatrix::from_csr(&a, 8);
    let mut wrong = NativeBackend { tile: 4 };
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        bsr_spmmm(&ab, &ab, &mut wrong)
    }));
    assert!(r.is_err(), "backend tile mismatch must be rejected");
}

#[test]
fn cli_parser_failure_modes() {
    use blazert::util::cli::{Args, OptSpec};
    const SPECS: &[OptSpec] =
        &[OptSpec { name: "n", help: "size", takes_value: true }];
    // Trailing option without value.
    let e = Args::parse_from(
        ["p".to_string(), "--n".to_string()].into_iter(),
        false,
        SPECS,
    );
    assert!(e.is_err());
    // Unparseable typed value surfaces the text.
    let a = Args::parse_from(
        ["p".to_string(), "--n=zz".to_string()].into_iter(),
        false,
        SPECS,
    )
    .unwrap();
    assert!(a.get_parsed_or("n", 0usize).unwrap_err().contains("zz"));
}
