//! Failure injection: corrupt inputs, bad geometry, contract violations.
//! The library must fail loudly and precisely, not corrupt results.

use blazert::gen::random_fixed_per_row;
use blazert::kernels::{spmmm, Strategy};
use blazert::runtime::Manifest;
use blazert::simulator::{Cache, CacheConfig};
use blazert::sparse::{CooMatrix, CsrMatrix};
use std::path::Path;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("blazert_fi_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn spmmm_rejects_dimension_mismatch() {
    let a = random_fixed_per_row(10, 20, 3, 1);
    let b = random_fixed_per_row(21, 10, 3, 2); // 20 != 21
    let r = std::panic::catch_unwind(|| spmmm(&a, &b, Strategy::Combined));
    assert!(r.is_err(), "mismatched inner dimension must panic");
}

#[test]
fn from_parts_rejects_corrupt_structures() {
    // Out-of-bounds column index.
    let r = std::panic::catch_unwind(|| {
        CsrMatrix::from_parts(1, 2, vec![0, 1], vec![5], vec![1.0])
    });
    assert!(r.is_err());
    // Non-monotone row_ptr.
    let r = std::panic::catch_unwind(|| {
        CsrMatrix::from_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 2.0])
    });
    assert!(r.is_err());
    // Duplicate column within a row.
    let r = std::panic::catch_unwind(|| {
        CsrMatrix::from_parts(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 2.0])
    });
    assert!(r.is_err());
}

#[test]
fn coo_rejects_out_of_bounds() {
    let mut m = CooMatrix::new(3, 3);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| m.push(0, 3, 1.0)));
    assert!(r.is_err());
}

#[test]
fn manifest_corruption_modes() {
    // Missing directory.
    assert!(Manifest::load(Path::new("/definitely/not/here")).is_err());

    // Garbled field.
    let d = tmpdir("garbled");
    std::fs::write(d.join("manifest.txt"), "name=x file\n").unwrap();
    assert!(Manifest::load(&d).is_err());

    // Non-numeric shape.
    let d2 = tmpdir("shape");
    std::fs::write(d2.join("manifest.txt"), "name=x file=x.hlo dtype=f32 args=axb\n").unwrap();
    assert!(Manifest::load(&d2).is_err());

    // Missing required key.
    let d3 = tmpdir("missing");
    std::fs::write(d3.join("manifest.txt"), "file=x.hlo dtype=f32 args=2x2\n").unwrap();
    assert!(Manifest::load(&d3).is_err());

    for d in [d, d2, d3] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn runtime_rejects_corrupt_hlo() {
    if !blazert::runtime::Runtime::artifacts_available() {
        eprintln!("[runtime_rejects_corrupt_hlo] no artifacts; skipping");
        return;
    }
    // Copy the real manifest but point an entry at corrupt HLO text.
    let d = tmpdir("badhlo");
    std::fs::write(
        d.join("manifest.txt"),
        "name=tile_mma file=bad.hlo.txt dtype=f32 args=64x32x32,64x32x32,64x32x32 tile=32 batch=64 groups=16 group_k=8 dense_n=256\n",
    )
    .unwrap();
    std::fs::write(d.join("bad.hlo.txt"), "HloModule garbage\nENTRY oops { broken }\n").unwrap();
    let rt = blazert::runtime::Runtime::load(&d);
    // Loading the manifest succeeds; compilation of the bad entry fails.
    let mut rt = rt.expect("manifest itself parses");
    let te = 64 * 32 * 32;
    let z = vec![0f32; te];
    let shape = [64usize, 32, 32];
    let err = rt.execute_f32("tile_mma", &[(&z, &shape), (&z, &shape), (&z, &shape)]);
    assert!(err.is_err(), "corrupt HLO must fail compilation");
    std::fs::remove_dir_all(d).ok();
}

#[test]
fn cache_config_validation() {
    // Non-power-of-two line size.
    let r = std::panic::catch_unwind(|| {
        Cache::new(CacheConfig { name: "X", size_bytes: 512, line_bytes: 48, assoc: 2 })
    });
    assert!(r.is_err());
    // Zero sets (assoc too large).
    let r = std::panic::catch_unwind(|| {
        Cache::new(CacheConfig { name: "X", size_bytes: 64, line_bytes: 64, assoc: 2 })
    });
    assert!(r.is_err());
}

#[test]
fn bsr_backend_tile_mismatch_is_checked() {
    use blazert::bsr::{bsr_spmmm, BsrMatrix, NativeBackend};
    let a = random_fixed_per_row(16, 16, 3, 1);
    let ab = BsrMatrix::from_csr(&a, 8);
    let mut wrong = NativeBackend { tile: 4 };
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        bsr_spmmm(&ab, &ab, &mut wrong)
    }));
    assert!(r.is_err(), "backend tile mismatch must be rejected");
}

#[test]
fn cli_parser_failure_modes() {
    use blazert::util::cli::{Args, OptSpec};
    const SPECS: &[OptSpec] =
        &[OptSpec { name: "n", help: "size", takes_value: true }];
    // Trailing option without value.
    let e = Args::parse_from(
        ["p".to_string(), "--n".to_string()].into_iter(),
        false,
        SPECS,
    );
    assert!(e.is_err());
    // Unparseable typed value surfaces the text.
    let a = Args::parse_from(
        ["p".to_string(), "--n=zz".to_string()].into_iter(),
        false,
        SPECS,
    )
    .unwrap();
    assert!(a.get_parsed_or("n", 0usize).unwrap_err().contains("zz"));
}

// ---------------------------------------------------------------------
// Plan-store corruption: every damaged on-disk entry must *decline to
// load* (bumping the store's `store_rejected` counter) and fall back
// bit-identically to the unplanned kernel — corruption may cost a
// symbolic rebuild, never correctness, and never a panic.
// ---------------------------------------------------------------------

use blazert::exec::{Partition, Workspace};
use blazert::expr::EvalContext;
use blazert::gen::fd_poisson_2d;
use blazert::model::Machine;
use blazert::plan::{PlanCache, PlanKey, PlanStore, SpmmmPlan};
use std::sync::Arc;

/// A store in a fresh directory holding one valid persisted plan for
/// `a · a` under the default evaluation shape, plus that entry's key.
fn seeded_store(tag: &str, a: &CsrMatrix) -> (std::path::PathBuf, Arc<PlanStore>, PlanKey) {
    let d = tmpdir(tag);
    let machine = Machine::sandy_bridge_i7_2600();
    let key = PlanKey::of(&machine, a, a, 1, Partition::default());
    let plan = SpmmmPlan::build(&machine, a, a, key, &mut Workspace::new());
    let store = Arc::new(PlanStore::open_default(&d).expect("store opens"));
    assert!(store.save(&plan), "seeding save succeeds");
    (d, store, key)
}

/// The corrupted entry must decline (`store_rejected` reaches
/// `expect_rejections` counting the explicit load probe plus the
/// evaluation's load-on-miss), and the evaluation must fall back to the
/// unplanned kernel with a bit-identical result.
fn assert_rejects_and_falls_back(
    store: &Arc<PlanStore>,
    key: &PlanKey,
    a: &CsrMatrix,
    expect_rejections: u64,
) {
    assert!(store.load(key).is_none(), "corrupt entry must decline to load");
    let cache = PlanCache::default();
    let mut ctx = EvalContext::new().with_plan_store(&cache, store);
    let mut out = CsrMatrix::new(0, 0);
    ctx.product_into(a, a, &mut out);
    let reference = spmmm(a, a, Strategy::Combined);
    assert!(out.approx_eq(&reference, 0.0), "fallback must be bit-identical to unplanned");
    let s = cache.stats();
    assert_eq!(s.disk_loads, 0, "nothing valid was recovered");
    assert_eq!(s.misses, 1, "the probe fell through to a cold miss");
    assert_eq!(store.stats().store_rejected, expect_rejections);
}

#[test]
fn plan_store_rejects_truncated_file() {
    let a = fd_poisson_2d(10);
    let (d, store, key) = seeded_store("plan_trunc", &a);
    let path = store.path_for(&key);
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert_rejects_and_falls_back(&store, &key, &a, 2);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn plan_store_rejects_flipped_checksum_byte() {
    let a = fd_poisson_2d(10);
    let (d, store, key) = seeded_store("plan_cksum", &a);
    let path = store.path_for(&key);
    let mut bytes = std::fs::read(&path).unwrap();
    // Word 2 (bytes 16..24) is the checksum; flipping any of its bits
    // must fail verification against the (intact) body.
    bytes[16] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    assert_rejects_and_falls_back(&store, &key, &a, 2);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn plan_store_rejects_flipped_payload_byte() {
    let a = fd_poisson_2d(10);
    let (d, store, key) = seeded_store("plan_payload", &a);
    let path = store.path_for(&key);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    assert_rejects_and_falls_back(&store, &key, &a, 2);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn plan_store_rejects_wrong_format_version() {
    let a = fd_poisson_2d(10);
    let (d, store, key) = seeded_store("plan_version", &a);
    let path = store.path_for(&key);
    let mut bytes = std::fs::read(&path).unwrap();
    // Word 1 (bytes 8..16) is the format version. The checksum covers
    // only the body, so this file is "valid" except for its version —
    // exercising the version gate specifically.
    bytes[8..16].copy_from_slice(&99u64.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    assert_rejects_and_falls_back(&store, &key, &a, 2);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn plan_store_rejects_colliding_key_with_mismatched_shape() {
    let a = fd_poisson_2d(10);
    let d = tmpdir("plan_collide");
    let machine = Machine::sandy_bridge_i7_2600();
    let key = PlanKey::of(&machine, &a, &a, 1, Partition::default());
    let store = Arc::new(PlanStore::open_default(&d).expect("store opens"));
    // Forge a store entry that sits under `key`'s filename, carries
    // `key` in its header, passes version and checksum — but whose
    // payload describes a different-shaped product (what a 64-bit
    // fingerprint collision between different structures would look
    // like on disk). The structural revalidation must refuse it.
    let big = fd_poisson_2d(14);
    let key_big = PlanKey::of(&machine, &big, &big, 1, Partition::default());
    let plan_big = SpmmmPlan::build(&machine, &big, &big, key_big, &mut Workspace::new());
    assert!(store.save_as(key, &plan_big));
    assert_rejects_and_falls_back(&store, &key, &a, 2);
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn plan_store_warm_scan_skips_corrupt_entries() {
    // A directory mixing one valid and one garbage entry: the warm
    // scan recovers the valid plan, rejects the garbage, and never
    // panics — the worst case of a damaged state dir is a partial warm
    // start.
    let a = fd_poisson_2d(10);
    let (d, store, _key) = seeded_store("plan_mixed", &a);
    std::fs::write(d.join("plan-0000000000000000.bzp"), b"garbage").unwrap();
    let cache = PlanCache::default();
    assert_eq!(cache.warm_from_dir(&store), 1, "the valid entry still loads");
    assert_eq!(store.stats().store_rejected, 1, "the garbage entry was rejected");
    std::fs::remove_dir_all(&d).ok();
}
