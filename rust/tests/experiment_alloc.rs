//! The harness's allocation gate, end to end: run the committed
//! plan-ablation definition quick-tier with a counting global allocator
//! installed (the same probe the `experiment` binary wires up) and hold
//! the run against the committed baseline — which pins
//! `steady_allocs = 0` on the CSR unplanned/warm/persisted rows *and*
//! the CSC warm/persisted rows, and `symbolic_builds = 0` on the
//! disk-warm rows of both formats. One `#[test]` so no
//! concurrent test perturbs the allocation counter.

use blazert::blazemark::{row_field, BenchRecord};
use blazert::harness::{
    compare, find_repo_file, run_experiment, ExperimentDef, RunOptions, RunTier,
};
use blazert::util::json::Json;
use blazert::util::CountingAlloc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn probe() -> usize {
    ALLOC.calls()
}

#[test]
fn committed_plan_definition_passes_its_baseline_with_zero_steady_allocs() {
    let def =
        ExperimentDef::load(&find_repo_file("experiments/plan_ablation.toml")).unwrap();
    let opts = RunOptions { tier: RunTier::Quick, alloc_probe: Some(probe), verbose: false };
    let rec = run_experiment(&def, &opts).unwrap();
    assert_eq!(rec.rows.len(), 28, "14 points × 2 workloads");

    // Cold points rebuild their plan per execution (allocating is their
    // design); every other point must refill without touching the heap.
    for row in &rec.rows {
        let mode = row_field(row, "plan_mode").and_then(Json::as_str).unwrap();
        let allocs = row_field(row, "steady_allocs").and_then(Json::as_f64);
        if mode == "cold" {
            assert!(allocs.is_none(), "cold rows make no steady-state claim");
        } else {
            assert_eq!(allocs, Some(0.0), "steady-state allocations on a {mode} row");
        }
    }

    // The committed baseline gates exactly these invariants — the same
    // check CI runs via `experiment compare`.
    let base =
        BenchRecord::load(&find_repo_file("baselines/experiments/plan_ablation.json"))
            .unwrap();
    let rep = compare(&base, &rec, &def.metrics);
    assert!(rep.passed(), "{}", rep.render());
    assert_eq!(rep.checked, 28, "20× steady_allocs + 8× symbolic_builds:\n{}", rep.render());
    assert!(rep.new_rows.is_empty(), "{}", rep.render());
}
