//! The engine's headline guarantee, pinned with a counting global
//! allocator: after one warm-up call, re-evaluating an expression tree
//! through a warm [`ExecPool`] performs **zero heap allocations** — on
//! the serial workspace path, on the parallel size-then-fill path, on
//! the planned CSC refill path, on the fused spMMM→SpMV pipeline, and
//! on the plan-cache hit path, which additionally performs **zero
//! symbolic work** (proven by the [`PlanCache::stats`] counters). This
//! file holds its tests in one `#[test]` so no concurrent test can
//! perturb the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use blazert::exec::{default_machine, ExecPool, Partition};
use blazert::expr::{
    cached_chain_vec_schedule, chain_vec_schedule, ChainVecLowering, EvalContext, FactorMeta,
    SparseOperand,
};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::spmv::spmv;
use blazert::kernels::{planned_fill_serial_csc, spmmm, Strategy};
use blazert::plan::{PlanCache, PlanStore};
use blazert::sparse::convert::csr_to_csc;
use blazert::sparse::{CscMatrix, CsrMatrix, SparseShape};
use std::borrow::Cow;
use std::sync::Arc;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn warm_pool_evaluation_allocates_nothing() {
    let pool = ExecPool::new(2);
    let (a, b) = operand_pair(Workload::RandomFixed5, 300, 7);
    let reference = spmmm(&a, &b, Strategy::Combined);
    let mut out = CsrMatrix::new(0, 0);

    // Serial workspace path (model-guided strategy, scratch-backed).
    let mut ctx = EvalContext::new().with_exec(&pool);
    (&a * &b).assign_to(&mut out, &mut ctx);
    (&a * &b).assign_to(&mut out, &mut ctx);
    let before = allocs();
    for _ in 0..5 {
        (&a * &b).assign_to(&mut out, &mut ctx);
    }
    assert_eq!(
        allocs(),
        before,
        "serial hot loop must not allocate after warm-up"
    );
    assert!(out.approx_eq(&reference, 0.0));

    // Parallel size-then-fill path on the same pool.
    let mut ctx = EvalContext::new().with_exec(&pool).with_threads(2);
    (&a * &b).assign_to(&mut out, &mut ctx);
    (&a * &b).assign_to(&mut out, &mut ctx);
    let before = allocs();
    for _ in 0..5 {
        (&a * &b).assign_to(&mut out, &mut ctx);
    }
    assert_eq!(
        allocs(),
        before,
        "parallel hot loop must not allocate after warm-up"
    );
    assert!(out.approx_eq(&reference, 0.0));

    // Plan-cache hit path: zero heap allocations AND zero symbolic
    // work. FD operands so the amortization hook approves the serial
    // plan; warm-up covers first sight (unplanned) and the one
    // symbolic build, then the hot loop must be pure refill.
    let (fa, fb) = operand_pair(Workload::FiveBandFd, 300, 11);
    let planned_reference = spmmm(&fa, &fb, Strategy::Combined);
    let cache = PlanCache::default();
    for threads in [1usize, 2] {
        let mut ctx = EvalContext::new()
            .with_exec(&pool)
            .with_threads(threads)
            .with_plan_cache(&cache);
        for _ in 0..3 {
            (&fa * &fb).assign_to(&mut out, &mut ctx);
        }
        let stats = cache.stats();
        let before = allocs();
        for _ in 0..5 {
            (&fa * &fb).assign_to(&mut out, &mut ctx);
        }
        assert_eq!(
            allocs(),
            before,
            "plan-hit hot loop must not allocate (threads={threads})"
        );
        let after = cache.stats();
        assert_eq!(
            after.symbolic_builds, stats.symbolic_builds,
            "plan-hit hot loop must not run the symbolic phase (threads={threads})"
        );
        assert_eq!(after.hits, stats.hits + 5, "every hot evaluation is a cache hit");
        assert!(out.approx_eq(&planned_reference, 0.0));
    }

    // Disk-warm path: a fresh cache warmed from an on-disk plan store
    // (the simulated restart). All allocation is confined to the load
    // phase — once `warm_from_dir` has run and the first refill has
    // warmed the scratch, repeated planned evaluations allocate
    // nothing and never run the symbolic phase.
    let dir = std::env::temp_dir().join(format!("blazert_alloc_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let seed_store = Arc::new(PlanStore::open_default(&dir).expect("store opens"));
        let seed_cache = PlanCache::default();
        seed_cache.attach_store(Arc::clone(&seed_store));
        pool.with_local(|ws| {
            for threads in [1usize, 2] {
                seed_cache.get_or_build(default_machine(), ws, &fa, &fb, threads, Partition::Flops);
            }
        });
        assert_eq!(seed_store.len(), 2, "seed plans persisted");
    }
    let store = Arc::new(PlanStore::open_default(&dir).expect("store reopens"));
    let warm_cache = PlanCache::default();
    assert_eq!(warm_cache.warm_from_dir(&store), 2, "restart recovers both plans");
    for threads in [1usize, 2] {
        let mut ctx = EvalContext::new()
            .with_exec(&pool)
            .with_threads(threads)
            .with_plan_cache(&warm_cache);
        for _ in 0..2 {
            (&fa * &fb).assign_to(&mut out, &mut ctx);
        }
        let before = allocs();
        for _ in 0..5 {
            (&fa * &fb).assign_to(&mut out, &mut ctx);
        }
        assert_eq!(
            allocs(),
            before,
            "disk-warm hot loop must not allocate (threads={threads})"
        );
        assert!(out.approx_eq(&planned_reference, 0.0));
    }
    let s = warm_cache.stats();
    assert_eq!(s.symbolic_builds, 0, "disk-warm path never runs the symbolic phase");
    assert_eq!(s.disk_loads, 2, "both plans came from the load phase");
    assert_eq!(s.misses, 0, "every planned evaluation hit the warmed cache");
    std::fs::remove_dir_all(&dir).ok();

    // Planned CSC refill path: the column-major twin of the plan-hit
    // loop above. Conversion and the symbolic build allocate up front;
    // the steady-state numeric refill through the frozen plan must not
    // — this is the invariant the csc rows of the plan-ablation
    // baseline gate with `steady_allocs = 0`.
    let (ca, cb) = (csr_to_csc(&fa), csr_to_csc(&fb));
    let csc_reference = csr_to_csc(&planned_reference);
    let csc_cache = PlanCache::default();
    let mut out_csc = CscMatrix::new(0, 0);
    let csc_plan = pool.with_local(|ws| {
        csc_cache.get_or_build_csc(default_machine(), ws, &ca, &cb, 1, Partition::Flops)
    });
    for _ in 0..2 {
        pool.with_local(|ws| {
            planned_fill_serial_csc(&csc_plan, &ca, &cb, &mut ws.plan_temp, &mut out_csc)
        });
    }
    let before = allocs();
    for _ in 0..5 {
        pool.with_local(|ws| {
            planned_fill_serial_csc(&csc_plan, &ca, &cb, &mut ws.plan_temp, &mut out_csc)
        });
    }
    assert_eq!(allocs(), before, "planned CSC refill hot loop must not allocate");
    assert!(out_csc.approx_eq(&csc_reference, 0.0));

    // Fused spMMM→SpMV pipeline: the workspace path, the parallel slab
    // path, and the plan-hit refill must all contract the chain against
    // x without materializing the intermediate or touching the heap.
    let x: Vec<f64> = (0..fb.cols()).map(|i| 1.0 + (i % 5) as f64).collect();
    let mut y = vec![0.0; fa.rows()];
    for threads in [1usize, 2] {
        let mut ctx = EvalContext::new().with_exec(&pool).with_threads(threads);
        for _ in 0..2 {
            ctx.fused_matvec(&fa, &fb, &x, &mut y);
        }
        let before = allocs();
        for _ in 0..5 {
            ctx.fused_matvec(&fa, &fb, &x, &mut y);
        }
        assert_eq!(
            allocs(),
            before,
            "fused hot loop must not allocate (threads={threads})"
        );
    }
    // Plan-hit fused path: zero heap allocations AND zero symbolic
    // work once the shared product plan is cached.
    let fused_cache = PlanCache::default();
    let mut ctx = EvalContext::new().with_exec(&pool).with_plan_cache(&fused_cache);
    for _ in 0..3 {
        ctx.fused_matvec(&fa, &fb, &x, &mut y);
    }
    let stats = fused_cache.stats();
    let before = allocs();
    for _ in 0..5 {
        ctx.fused_matvec(&fa, &fb, &x, &mut y);
    }
    assert_eq!(allocs(), before, "planned fused hot loop must not allocate");
    let after = fused_cache.stats();
    assert_eq!(
        after.symbolic_builds, stats.symbolic_builds,
        "planned fused hot loop must not run the symbolic phase"
    );
    assert_eq!(after.hits, stats.hits + 5, "every hot fused evaluation is a plan hit");

    // Chain-times-vector sugar: the flattened factor list is staged in
    // recycled workspace scratch, so the warm two-factor pipeline
    // expression — build, flatten, arbitrate, fused contraction —
    // allocates nothing end to end.
    let mut ctx = EvalContext::new().with_exec(&pool);
    let mut y_sugar = vec![0.0; fa.rows()];
    for _ in 0..2 {
        (&fa * &fb * &x).eval_into_ctx(&mut y_sugar, &mut ctx);
    }
    let before = allocs();
    for _ in 0..5 {
        (&fa * &fb * &x).eval_into_ctx(&mut y_sugar, &mut ctx);
    }
    assert_eq!(allocs(), before, "warm chain-sugar pipeline must not allocate");

    // Streamed multi-hop chain: the three-factor pipeline the chain DP
    // lowers onto [`EvalContext::streamed_matvec`]. Spine rows stream
    // through the workspace's recycled row buffer and per-hop
    // accumulators — no intermediate matrix is ever materialized and
    // the warm loop never touches the heap (the invariant the
    // chain-fusion baseline gates with `intermediate_allocs = 0`).
    let meta = [FactorMeta::of(&fa), FactorMeta::of(&fb), FactorMeta::of(&fa)];
    let schedule = chain_vec_schedule(default_machine(), &meta, 1);
    assert!(
        matches!(schedule.lowering, ChainVecLowering::Stream { .. }),
        "single-consumer FD chain must stream"
    );
    let ab = spmmm(&fa, &fb, Strategy::Combined);
    let abc = spmmm(&ab, &fa, Strategy::Combined);
    let mut want_chain = vec![0.0; fa.rows()];
    spmv(&abc, &x, &mut want_chain);
    let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    let factors = [Cow::Borrowed(&fa), Cow::Borrowed(&fb), Cow::Borrowed(&fa)];
    let mut y_chain = vec![0.0; fa.rows()];
    for threads in [1usize, 2] {
        let mut ctx = EvalContext::new().with_exec(&pool).with_threads(threads);
        for _ in 0..2 {
            ctx.streamed_matvec(&factors, &x, &mut y_chain);
        }
        let before = allocs();
        for _ in 0..5 {
            ctx.streamed_matvec(&factors, &x, &mut y_chain);
        }
        assert_eq!(
            allocs(),
            before,
            "streamed chain hot loop must not allocate (threads={threads})"
        );
        assert_eq!(bits(&y_chain), bits(&want_chain), "streamed chain stays bit-identical");
    }

    // Warm ≥3-factor chain sugar: the DP-level schedule now comes from
    // the thread-local pattern-keyed memo, so the hot loop skips the
    // O(n³) planning pass and its three n×n tables entirely — build,
    // flatten, cached-schedule lookup, streamed contraction: zero heap
    // allocations end to end, bit-identical to the materialized
    // reference.
    let sched = cached_chain_vec_schedule(default_machine(), &factors, 1);
    assert_eq!(sched.lowering, schedule.lowering, "memo agrees with the direct DP");
    let mut ctx = EvalContext::new().with_exec(&pool);
    let mut y3 = vec![0.0; fa.rows()];
    for _ in 0..2 {
        (&fa * &fb * &fa * &x[..]).eval_into_ctx(&mut y3, &mut ctx);
    }
    let before = allocs();
    for _ in 0..5 {
        (&fa * &fb * &fa * &x[..]).eval_into_ctx(&mut y3, &mut ctx);
    }
    assert_eq!(allocs(), before, "warm 3-factor chain sugar must not allocate");
    assert_eq!(bits(&y3), bits(&want_chain), "cached chain schedule stays bit-identical");
}
