//! The engine's headline guarantee, pinned with a counting global
//! allocator: after one warm-up call, re-evaluating an expression tree
//! through a warm [`ExecPool`] performs **zero heap allocations** — on
//! the serial workspace path and on the parallel size-then-fill path
//! alike. This file holds a single test so no concurrent test can
//! perturb the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use blazert::exec::ExecPool;
use blazert::expr::{EvalContext, SparseOperand};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::{spmmm, Strategy};
use blazert::sparse::CsrMatrix;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn warm_pool_evaluation_allocates_nothing() {
    let pool = ExecPool::new(2);
    let (a, b) = operand_pair(Workload::RandomFixed5, 300, 7);
    let reference = spmmm(&a, &b, Strategy::Combined);
    let mut out = CsrMatrix::new(0, 0);

    // Serial workspace path (model-guided strategy, scratch-backed).
    let mut ctx = EvalContext::new().with_exec(&pool);
    (&a * &b).assign_to(&mut out, &mut ctx);
    (&a * &b).assign_to(&mut out, &mut ctx);
    let before = allocs();
    for _ in 0..5 {
        (&a * &b).assign_to(&mut out, &mut ctx);
    }
    assert_eq!(
        allocs(),
        before,
        "serial hot loop must not allocate after warm-up"
    );
    assert!(out.approx_eq(&reference, 0.0));

    // Parallel size-then-fill path on the same pool.
    let mut ctx = EvalContext::new().with_exec(&pool).with_threads(2);
    (&a * &b).assign_to(&mut out, &mut ctx);
    (&a * &b).assign_to(&mut out, &mut ctx);
    let before = allocs();
    for _ in 0..5 {
        (&a * &b).assign_to(&mut out, &mut ctx);
    }
    assert_eq!(
        allocs(),
        before,
        "parallel hot loop must not allocate after warm-up"
    );
    assert!(out.approx_eq(&reference, 0.0));
}
