//! Property-based tests over the crate's core invariants (seeded
//! shrinking harness in `util::prop`; replay any failure with
//! `BLAZERT_PROP_SEED=<seed> BLAZERT_PROP_CASES=1 cargo test`).

use blazert::bsr::{bsr_spmmm, BsrMatrix, NativeBackend};
use blazert::gen::random_fixed_per_row;
use blazert::kernels::flops::{nnz_estimate, required_multiplications, spmmm_flops};
use blazert::kernels::{spmmm, Strategy};
use blazert::simulator::Hierarchy;
use blazert::sparse::convert::{csc_to_csr, csr_to_csc};
use blazert::sparse::{CooMatrix, CsrMatrix, DenseMatrix, SparseShape};
use blazert::util::prop::{check_default, assert_allclose};
use blazert::util::rng::Pcg64;

/// Arbitrary sparse matrix from a seeded RNG.
fn arb_matrix(rng: &mut Pcg64, max_dim: usize) -> CsrMatrix {
    let rows = rng.range(1, max_dim);
    let cols = rng.range(1, max_dim);
    let per_row = rng.below(cols.min(8)) + usize::from(rng.bernoulli(0.8));
    random_fixed_per_row(rows, cols, per_row, rng.next_u64())
}

#[test]
fn prop_conversion_round_trip() {
    check_default("csr<->csc round trip", |rng, _| {
        let a = arb_matrix(rng, 60);
        let back = csc_to_csr(&csr_to_csc(&a));
        if back.approx_eq(&a, 0.0) {
            Ok(())
        } else {
            Err(format!("round trip differs for {}x{}", a.rows(), a.cols()))
        }
    });
}

#[test]
fn prop_coo_canonicalization() {
    check_default("coo->csr == coo->csc", |rng, _| {
        let rows = rng.range(1, 40);
        let cols = rng.range(1, 40);
        let mut coo = CooMatrix::new(rows, cols);
        for _ in 0..rng.below(200) {
            coo.push(rng.below(rows), rng.below(cols), rng.f64_range(-1.0, 1.0));
        }
        let csr = coo.to_csr();
        let csc = coo.to_csc();
        let d1 = DenseMatrix::from_csr(&csr);
        let d2 = DenseMatrix::from_csc(&csc);
        if d1.max_abs_diff(&d2) < 1e-12 && csr.nnz() == csc.nnz() {
            Ok(())
        } else {
            Err("coo canonicalization mismatch".into())
        }
    });
}

#[test]
fn prop_nnz_estimate_upper_bound() {
    check_default("nnz estimate never underestimates", |rng, _| {
        let a = arb_matrix(rng, 40);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 40), rng.below(6) + 1, rng.next_u64());
        let est = nnz_estimate(&a, &b);
        let c = spmmm(&a, &b, Strategy::BruteForceDouble);
        if c.nnz() <= est {
            Ok(())
        } else {
            Err(format!("estimate {est} < actual {}", c.nnz()))
        }
    });
}

#[test]
fn prop_strategy_equivalence() {
    check_default("all storing strategies identical", |rng, _| {
        let a = arb_matrix(rng, 50);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 50), rng.below(6) + 1, rng.next_u64());
        let reference = spmmm(&a, &b, Strategy::BruteForceDouble);
        for s in Strategy::ALL {
            let c = spmmm(&a, &b, s);
            if !c.approx_eq(&reference, 0.0) {
                return Err(format!("{} differs", s.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_matches_dense_oracle() {
    check_default("spMMM == dense oracle", |rng, _| {
        let a = arb_matrix(rng, 30);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 30), rng.below(5) + 1, rng.next_u64());
        let c = spmmm(&a, &b, Strategy::Combined);
        let oracle = DenseMatrix::from_csr(&a).matmul(&DenseMatrix::from_csr(&b));
        let got = DenseMatrix::from_csr(&c);
        if got.max_abs_diff(&oracle) < 1e-10 {
            Ok(())
        } else {
            Err(format!("diff {}", got.max_abs_diff(&oracle)))
        }
    });
}

#[test]
fn prop_flop_count_duality() {
    // Σ ā_k b̄_k is symmetric under (A,B) -> (Bᵀ,Aᵀ).
    check_default("flop count transpose duality", |rng, _| {
        let a = arb_matrix(rng, 40);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 40), rng.below(5) + 1, rng.next_u64());
        let m1 = required_multiplications(&a, &b);
        let m2 = required_multiplications(&b.transpose(), &a.transpose());
        if m1 == m2 {
            Ok(())
        } else {
            Err(format!("{m1} != {m2}"))
        }
    });
}

#[test]
fn prop_append_finalize_valid_csr() {
    check_default("append/finalize yields valid CSR", |rng, _| {
        let rows = rng.range(1, 30);
        let cols = rng.range(1, 30);
        let mut m = CsrMatrix::new(rows, cols);
        let mut expected = Vec::new();
        for r in 0..rows {
            let k = rng.below(cols.min(6) + 1);
            for c in rng.distinct_sorted(k, cols) {
                let v = rng.nonzero_value();
                m.append(c, v);
                expected.push((r, c, v));
            }
            m.finalize_row();
        }
        if !m.is_finalized() {
            return Err("not finalized".into());
        }
        let got: Vec<(usize, usize, f64)> = m.iter().collect();
        if got == expected {
            Ok(())
        } else {
            Err("iteration mismatch".into())
        }
    });
}

#[test]
fn prop_transpose_involution() {
    check_default("transpose twice is identity", |rng, _| {
        let a = arb_matrix(rng, 50);
        if a.transpose().transpose().approx_eq(&a, 0.0) {
            Ok(())
        } else {
            Err("Aᵀᵀ != A".into())
        }
    });
}

#[test]
fn prop_bsr_equals_scalar() {
    check_default("BSR product == scalar product", |rng, _| {
        let a = arb_matrix(rng, 40);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 40), rng.below(5) + 1, rng.next_u64());
        let tile = [1usize, 2, 4, 8][rng.below(4)];
        let ab = BsrMatrix::from_csr(&a, tile);
        let bb = BsrMatrix::from_csr(&b, tile);
        let mut backend = NativeBackend { tile };
        let c = bsr_spmmm(&ab, &bb, &mut backend).map_err(|e| e.to_string())?;
        let reference = spmmm(&a, &b, Strategy::Combined);
        let d1 = DenseMatrix::from_csr(&c.to_csr());
        let d2 = DenseMatrix::from_csr(&reference);
        let rel = d1.max_abs_diff(&d2) / d2.frobenius().max(1.0);
        if rel < 1e-5 {
            Ok(())
        } else {
            Err(format!("tile {tile}: rel {rel}"))
        }
    });
}

#[test]
fn prop_simulator_conservation() {
    check_default("cache simulator invariants", |rng, _| {
        let a = arb_matrix(rng, 40);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 40), rng.below(5) + 1, rng.next_u64());
        let mut h = Hierarchy::sandy_bridge();
        let _ = spmmm(&a, &b, Strategy::Combined); // warm nothing; just compute
        let _ = blazert::kernels::spmmm_traced(&a, &b, Strategy::Combined, &mut h);
        let r = h.report();
        // hits + misses = accesses at L1; inner misses = outer accesses.
        let l1 = &r.levels[0];
        if l1.hits + l1.misses == 0 {
            // Structurally empty operands perform no traced accesses —
            // vacuously fine.
            return if a.nnz() == 0 || b.nnz() == 0 {
                Ok(())
            } else {
                Err("no L1 accesses observed".into())
            };
        }
        let l2 = &r.levels[1];
        // L2 accesses = L1 misses (fills) — write-back installs are
        // charged separately, so accesses can't exceed misses.
        if l2.hits + l2.misses != l1.misses {
            return Err(format!(
                "L2 accesses {} != L1 misses {}",
                l2.hits + l2.misses,
                l1.misses
            ));
        }
        // Memory fills <= L3 misses (write-backs add, fills don't).
        if r.mem_fills > r.levels[2].misses {
            return Err("memory fills exceed L3 misses".into());
        }
        Ok(())
    });
}

#[test]
fn prop_scalar_expression_linearity() {
    use blazert::expr::Expression;
    check_default("(s*A)*B == s*(A*B)", |rng, _| {
        let a = arb_matrix(rng, 25);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 25), rng.below(4) + 1, rng.next_u64());
        let s = rng.f64_range(0.5, 2.0);
        let lhs = {
            let sa = (s * &a).eval();
            spmmm(&sa, &b, Strategy::Combined)
        };
        let rhs = (s * &spmmm(&a, &b, Strategy::Combined)).eval();
        let d1 = DenseMatrix::from_csr(&lhs);
        let d2 = DenseMatrix::from_csr(&rhs);
        assert_allclose(d1.data(), d2.data(), 1e-12, 1e-12)
    });
}

#[test]
fn prop_nested_expression_trees_match_dense_oracle() {
    use blazert::expr::{Expression, TransposeExt};

    fn dmap(x: &DenseMatrix, y: &DenseMatrix, f: impl Fn(f64, f64) -> f64) -> DenseMatrix {
        DenseMatrix::from_vec(
            x.rows(),
            x.cols(),
            x.data().iter().zip(y.data()).map(|(p, q)| f(*p, *q)).collect(),
        )
    }
    fn dscale(x: &DenseMatrix, s: f64) -> DenseMatrix {
        DenseMatrix::from_vec(x.rows(), x.cols(), x.data().iter().map(|v| s * v).collect())
    }
    fn dtrans(x: &DenseMatrix) -> DenseMatrix {
        let mut out = vec![0.0; x.rows() * x.cols()];
        for r in 0..x.rows() {
            for c in 0..x.cols() {
                out[c * x.rows() + r] = x[(r, c)];
            }
        }
        DenseMatrix::from_vec(x.cols(), x.rows(), out)
    }

    check_default("random nested expression trees == dense oracle", |rng, _| {
        let n1 = rng.range(2, 20);
        let n2 = rng.range(2, 20);
        let n3 = rng.range(2, 20);
        let n4 = rng.range(2, 20);
        let a = random_fixed_per_row(n1, n2, rng.below(4) + 1, rng.next_u64());
        let a2 = random_fixed_per_row(n1, n2, rng.below(4) + 1, rng.next_u64());
        let b = random_fixed_per_row(n2, n3, rng.below(4) + 1, rng.next_u64());
        let c = random_fixed_per_row(n3, n4, rng.below(4) + 1, rng.next_u64());
        let d = random_fixed_per_row(n1, n3, rng.below(4) + 1, rng.next_u64());
        let e = random_fixed_per_row(n4, n2, rng.below(4) + 1, rng.next_u64());
        let s = rng.f64_range(-2.0, 2.0);
        let da = DenseMatrix::from_csr(&a);
        let da2 = DenseMatrix::from_csr(&a2);
        let db = DenseMatrix::from_csr(&b);
        let dc = DenseMatrix::from_csr(&c);
        let dd = DenseMatrix::from_csr(&d);
        let de = DenseMatrix::from_csr(&e);

        let cases: Vec<(&str, blazert::sparse::CsrMatrix, DenseMatrix)> = vec![
            ("A*B + D", (&a * &b + &d).eval(), dmap(&da.matmul(&db), &dd, |x, y| x + y)),
            ("A*B*C", (&a * &b * &c).eval(), da.matmul(&db).matmul(&dc)),
            (
                "s*(A*B) - D",
                (s * (&a * &b) - &d).eval(),
                dmap(&dscale(&da.matmul(&db), s), &dd, |x, y| x - y),
            ),
            (
                "(A+A2)*B",
                ((&a + &a2) * &b).eval(),
                dmap(&da, &da2, |x, y| x + y).matmul(&db),
            ),
            ("A*E^T", (&a * &e.t()).eval(), da.matmul(&dtrans(&de))),
        ];
        for (name, got, want) in cases {
            let diff = DenseMatrix::from_csr(&got).max_abs_diff(&want);
            if diff > 1e-9 {
                return Err(format!("tree '{name}' differs from oracle by {diff}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chain_plan_never_exceeds_worse_association() {
    use blazert::expr::schedule::pair_cost;
    use blazert::expr::{chain_plan, FactorMeta};
    use blazert::model::Machine;

    check_default("chain plan <= worse 3-chain association", |rng, _| {
        let machine = Machine::sandy_bridge_i7_2600();
        let dims: Vec<usize> = (0..4).map(|_| rng.range(1, 400)).collect();
        let metas: Vec<FactorMeta> = (0..3)
            .map(|i| {
                let dense = dims[i] * dims[i + 1];
                FactorMeta {
                    rows: dims[i],
                    cols: dims[i + 1],
                    nnz: rng.below(dense.max(1) + 1) as f64,
                }
            })
            .collect();
        let (c_ab, ab) = pair_cost(&machine, &metas[0], &metas[1]);
        let (c_ab_c, _) = pair_cost(&machine, &ab, &metas[2]);
        let left = c_ab + c_ab_c;
        let (c_bc, bc) = pair_cost(&machine, &metas[1], &metas[2]);
        let (c_a_bc, _) = pair_cost(&machine, &metas[0], &bc);
        let right = c_bc + c_a_bc;
        let plan = chain_plan(&machine, &metas);
        let worse = left.max(right);
        let best = left.min(right);
        if plan.cost > worse * (1.0 + 1e-12) {
            return Err(format!("plan cost {} exceeds worse association {}", plan.cost, worse));
        }
        if plan.cost > best * (1.0 + 1e-9) + 1e-300 {
            return Err(format!("plan cost {} misses best association {}", plan.cost, best));
        }
        Ok(())
    });
}

#[test]
fn prop_assign_to_matches_eval() {
    use blazert::expr::{EvalContext, Expression, SparseOperand};

    check_default("assign_to == eval for product graphs", |rng, _| {
        let a = arb_matrix(rng, 30);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 30), rng.below(4) + 1, rng.next_u64());
        let expr = &a * &b;
        let reference = expr.eval();
        let mut out = CsrMatrix::new(0, 0);
        expr.assign_to(&mut out, &mut EvalContext::new());
        if !out.approx_eq(&reference, 0.0) {
            return Err("assign_to differs from eval".into());
        }
        Ok(())
    });
}

#[test]
fn prop_flops_formula_vs_naive_count() {
    check_default("2x mults == spmmm_flops", |rng, _| {
        let a = arb_matrix(rng, 30);
        let b = random_fixed_per_row(a.cols(), rng.range(1, 30), rng.below(4) + 1, rng.next_u64());
        if spmmm_flops(&a, &b) == 2 * required_multiplications(&a, &b) {
            Ok(())
        } else {
            Err("flops formula broken".into())
        }
    });
}

/// Rebuild `m` with fresh random values on the identical structure.
fn with_random_values(m: &CsrMatrix, rng: &mut Pcg64) -> CsrMatrix {
    CsrMatrix::from_parts(
        m.rows(),
        m.cols(),
        m.row_ptr().to_vec(),
        m.col_idx().to_vec(),
        (0..m.nnz()).map(|_| rng.nonzero_value()).collect(),
    )
}

#[test]
fn prop_fingerprint_invariant_under_values() {
    check_default("fingerprint ignores values", |rng, _| {
        let a = arb_matrix(rng, 50);
        let b = with_random_values(&a, rng);
        if a.pattern_fingerprint() != b.pattern_fingerprint() {
            return Err(format!(
                "same {}x{} structure, different values => different fingerprint",
                a.rows(),
                a.cols()
            ));
        }
        // The invariance carries through the CSC form too.
        if csr_to_csc(&a).pattern_fingerprint() != csr_to_csc(&b).pattern_fingerprint() {
            return Err("CSC fingerprint saw the values".into());
        }
        Ok(())
    });
}

#[test]
fn prop_fingerprint_sensitive_to_one_moved_nnz() {
    check_default("single moved nnz changes the hash", |rng, _| {
        let a = arb_matrix(rng, 50);
        if a.nnz() == 0 {
            return Ok(());
        }
        // Pick a random stored entry and move it to a column its row
        // does not populate (skip rows that are already full).
        let entry = rng.below(a.nnz());
        let row = match a.row_ptr().iter().position(|&p| p > entry) {
            Some(p) => p - 1,
            None => return Ok(()),
        };
        let (idx, _) = a.row(row);
        if idx.len() == a.cols() {
            return Ok(());
        }
        let free = (0..a.cols())
            .filter(|c| !idx.contains(c))
            .nth(rng.below(a.cols() - idx.len()))
            .expect("a free column exists");
        let mut cols: Vec<usize> = idx.to_vec();
        cols[entry - a.row_ptr()[row]] = free;
        cols.sort_unstable();
        let mut all = a.col_idx().to_vec();
        all[a.row_ptr()[row]..a.row_ptr()[row + 1]].copy_from_slice(&cols);
        let moved = CsrMatrix::from_parts(
            a.rows(),
            a.cols(),
            a.row_ptr().to_vec(),
            all,
            a.values().to_vec(),
        );
        if a.pattern_fingerprint().hash == moved.pattern_fingerprint().hash {
            return Err(format!(
                "moving one nnz of a {}x{} matrix kept the hash",
                a.rows(),
                a.cols()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fingerprint_stable_across_csr_csc_round_trip() {
    check_default("fingerprint survives csr->csc->csr", |rng, _| {
        let a = arb_matrix(rng, 50);
        let back = csc_to_csr(&csr_to_csc(&a));
        if a.pattern_fingerprint() != back.pattern_fingerprint() {
            return Err("round trip changed the CSR fingerprint".into());
        }
        // And the CSC fingerprint is itself deterministic across
        // independent conversions of the same structure.
        if csr_to_csc(&a).pattern_fingerprint() != csr_to_csc(&back).pattern_fingerprint() {
            return Err("round trip changed the CSC fingerprint".into());
        }
        Ok(())
    });
}
