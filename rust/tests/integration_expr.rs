//! Integration: the Smart-Expression-Template layer end to end.

use blazert::expr::vector::{cg, dot, norm2};
use blazert::expr::{Expression, TransposeExt};
use blazert::gen::{fd_poisson_2d, fd_rhs_ones, random_fixed_per_row};
use blazert::kernels::{spmmm, Strategy};
use blazert::sparse::convert::csr_to_csc;
use blazert::sparse::{DenseMatrix, SparseShape};

#[test]
fn listing_one_equivalence() {
    // C = A * B via expressions == direct kernel call.
    let a = random_fixed_per_row(128, 128, 5, 1);
    let b = random_fixed_per_row(128, 128, 5, 2);
    let c_expr = (&a * &b).eval();
    let c_kernel = spmmm(&a, &b, Strategy::Combined);
    assert!(c_expr.approx_eq(&c_kernel, 0.0));
}

#[test]
fn composite_expression_pipeline() {
    // G = (J * M) * J^T with scaling and addition mixed in.
    let j = random_fixed_per_row(60, 90, 4, 3);
    let m = DenseMatrix::identity(90).to_csr();
    let jt = j.t().eval();
    let jm = (&j * &m).eval();
    let g = (&jm * &jt).eval();
    let g_scaled = (2.0 * &g).eval();
    let g_sum = (&g + &g).eval();
    assert!(g_scaled.approx_eq(&g_sum, 1e-12), "2G == G+G");
    // Symmetry of J J^T.
    assert!(g.approx_eq(&g.transpose(), 1e-12));
}

#[test]
fn mixed_order_assignment_matches_rowmajor() {
    let a = random_fixed_per_row(70, 80, 5, 5);
    let b = random_fixed_per_row(80, 50, 4, 6);
    let b_csc = csr_to_csc(&b);
    let mixed = (&a * &b_csc).eval();
    let direct = (&a * &b).eval();
    assert!(mixed.approx_eq(&direct, 0.0));
    // CSC x CSC path.
    let a_csc = csr_to_csc(&a);
    let both_csc = (&a_csc * &b_csc).eval();
    assert!(
        DenseMatrix::from_csc(&both_csc).max_abs_diff(&DenseMatrix::from_csr(&direct)) < 1e-12
    );
}

#[test]
fn subtraction_cancellation_prunes_structurally() {
    let a = random_fixed_per_row(30, 30, 5, 7);
    let z = (&a - &a).eval();
    assert_eq!(z.nnz(), 0);
}

#[test]
fn spmv_expression_in_cg() {
    // Full CG through the expression layer pieces on the FD system.
    let k = 24;
    let a = fd_poisson_2d(k);
    let b = fd_rhs_ones(k);
    let (x, iters, _res) = cg(&a, &b, 1e-9, 5000);
    assert!(iters < 5000);
    let ax = (&a * &x).eval();
    let r: Vec<f64> = ax.iter().zip(&b).map(|(p, q)| p - q).collect();
    assert!(norm2(&r) < 1e-6);
    assert!(dot(&x, &b) > 0.0, "energy positive");
}

#[test]
fn expression_objects_are_cheap() {
    // Building an expression must not touch the data (laziness): the
    // expression object is Copy and tiny.
    let a = random_fixed_per_row(1000, 1000, 5, 9);
    let b = random_fixed_per_row(1000, 1000, 5, 10);
    let e = &a * &b;
    let e2 = e; // Copy
    assert!(std::mem::size_of_val(&e) <= 2 * std::mem::size_of::<usize>());
    let _ = (e, e2);
}
