//! Integration: the Smart-Expression-Template layer end to end.

use blazert::expr::vector::{cg, dot, norm2};
use blazert::expr::{choose_strategy, EvalContext, Expression, SparseOperand, TransposeExt};
use blazert::gen::{fd_poisson_2d, fd_rhs_ones, random_fixed_per_row};
use blazert::kernels::tracer::CountingTracer;
use blazert::kernels::{flops, spmmm, Strategy};
use blazert::model::Machine;
use blazert::simulator::Hierarchy;
use blazert::sparse::convert::csr_to_csc;
use blazert::sparse::{CsrMatrix, DenseMatrix, SparseShape};

#[test]
fn listing_one_equivalence() {
    // C = A * B via expressions == direct kernel call.
    let a = random_fixed_per_row(128, 128, 5, 1);
    let b = random_fixed_per_row(128, 128, 5, 2);
    let c_expr = (&a * &b).eval();
    let c_kernel = spmmm(&a, &b, Strategy::Combined);
    assert!(c_expr.approx_eq(&c_kernel, 0.0));
}

#[test]
fn composite_expression_pipeline() {
    // G = (J * M) * J^T with scaling and addition mixed in.
    let j = random_fixed_per_row(60, 90, 4, 3);
    let m = DenseMatrix::identity(90).to_csr();
    let jt = j.t().eval();
    let jm = (&j * &m).eval();
    let g = (&jm * &jt).eval();
    let g_scaled = (2.0 * &g).eval();
    let g_sum = (&g + &g).eval();
    assert!(g_scaled.approx_eq(&g_sum, 1e-12), "2G == G+G");
    // Symmetry of J J^T.
    assert!(g.approx_eq(&g.transpose(), 1e-12));
}

#[test]
fn mixed_order_assignment_matches_rowmajor() {
    let a = random_fixed_per_row(70, 80, 5, 5);
    let b = random_fixed_per_row(80, 50, 4, 6);
    let b_csc = csr_to_csc(&b);
    let mixed = (&a * &b_csc).eval();
    let direct = (&a * &b).eval();
    assert!(mixed.approx_eq(&direct, 0.0));
    // CSC x CSC path.
    let a_csc = csr_to_csc(&a);
    let both_csc = (&a_csc * &b_csc).eval();
    assert!(
        DenseMatrix::from_csc(&both_csc).max_abs_diff(&DenseMatrix::from_csr(&direct)) < 1e-12
    );
}

#[test]
fn subtraction_cancellation_prunes_structurally() {
    let a = random_fixed_per_row(30, 30, 5, 7);
    let z = (&a - &a).eval();
    assert_eq!(z.nnz(), 0);
}

#[test]
fn spmv_expression_in_cg() {
    // Full CG through the expression layer pieces on the FD system.
    let k = 24;
    let a = fd_poisson_2d(k);
    let b = fd_rhs_ones(k);
    let (x, iters, _res) = cg(&a, &b, 1e-9, 5000);
    assert!(iters < 5000);
    let ax = (&a * &x).eval();
    let r: Vec<f64> = ax.iter().zip(&b).map(|(p, q)| p - q).collect();
    assert!(norm2(&r) < 1e-6);
    assert!(dot(&x, &b) > 0.0, "energy positive");
}

#[test]
fn expression_objects_are_cheap() {
    // Building an expression must not touch the data (laziness): the
    // expression object is Copy and tiny.
    let a = random_fixed_per_row(1000, 1000, 5, 9);
    let b = random_fixed_per_row(1000, 1000, 5, 10);
    let e = &a * &b;
    let e2 = e; // Copy
    assert!(std::mem::size_of_val(&e) <= 2 * std::mem::size_of::<usize>());
    // Nested graphs stay allocation-free too: a three-factor chain is
    // three references, nothing else.
    let c = random_fixed_per_row(1000, 1000, 5, 11);
    let chain = &a * &b * &c;
    assert!(std::mem::size_of_val(&chain) <= 3 * std::mem::size_of::<usize>());
    let _ = (e, e2, chain);
}

#[test]
fn composable_graphs_match_dense_oracle() {
    // Acceptance: `(&a * &b + &c).eval()` and `(&a * &b * &c).eval()`
    // compile and match the dense oracle without intermediate `.eval()`.
    let a = random_fixed_per_row(40, 40, 4, 31);
    let b = random_fixed_per_row(40, 40, 4, 32);
    let c = random_fixed_per_row(40, 40, 4, 33);
    let da = DenseMatrix::from_csr(&a);
    let db = DenseMatrix::from_csr(&b);
    let dc = DenseMatrix::from_csr(&c);

    let sum = (&a * &b + &c).eval();
    let prod = da.matmul(&db);
    for r in 0..40 {
        for col in 0..40 {
            assert!((sum.get(r, col) - (prod[(r, col)] + dc[(r, col)])).abs() < 1e-10);
        }
    }

    let chain = (&a * &b * &c).eval();
    let oracle = prod.matmul(&dc);
    assert!(DenseMatrix::from_csr(&chain).max_abs_diff(&oracle) < 1e-9);

    // Deep nesting with scaling and transpose in one graph.
    let deep = (2.0 * (&a * &b) + &c.t()).eval();
    for r in 0..40 {
        for col in 0..40 {
            assert!((deep.get(r, col) - (2.0 * prod[(r, col)] + dc[(col, r)])).abs() < 1e-10);
        }
    }
}

#[test]
fn model_guided_strategy_differs_between_workloads() {
    // Acceptance: assign-time strategy selection is driven by the
    // model/flops estimates — an FD stencil (tight touched regions)
    // selects MinMax while a wide random workload selects Sort.
    let machine = Machine::sandy_bridge_i7_2600();
    let fd = fd_poisson_2d(8);
    let s_fd = choose_strategy(&machine, &fd, &fd);
    let ar = random_fixed_per_row(256, 256, 5, 41);
    let br = random_fixed_per_row(256, 256, 5, 42);
    let s_rand = choose_strategy(&machine, &ar, &br);
    assert_eq!(s_fd, Strategy::MinMax, "banded FD stencil favors the MinMax scan");
    assert_eq!(s_rand, Strategy::Sort, "wide random rows favor Sort");
    assert_ne!(s_fd, s_rand);
    // Both choices produce the identical result (store invariant), so
    // the model can never hurt correctness.
    let via_model = (&ar * &br).eval();
    assert!(via_model.approx_eq(&spmmm(&ar, &br, Strategy::Combined), 0.0));
}

#[test]
fn eval_context_threads_and_strategy_override() {
    let a = random_fixed_per_row(300, 300, 5, 51);
    let b = random_fixed_per_row(300, 300, 5, 52);
    let serial = (&a * &b).eval();
    let parallel = (&a * &b).eval_with(&mut EvalContext::new().with_threads(4));
    assert!(parallel.approx_eq(&serial, 0.0));
    for strategy in [Strategy::MinMax, Strategy::Sort, Strategy::Combined] {
        let forced = (&a * &b).eval_with(&mut EvalContext::using(strategy));
        assert!(forced.approx_eq(&serial, 0.0));
    }
}

#[test]
fn tracer_replays_whole_expression_trees() {
    // A counting tracer sees exactly the flops of both products in the
    // chain; the cache simulator plugs in the same way.
    let a = random_fixed_per_row(60, 60, 4, 61);
    let b = random_fixed_per_row(60, 60, 4, 62);
    let c = random_fixed_per_row(60, 60, 4, 63);
    let serial = (&a * &b * &c).eval();

    let mut counter = CountingTracer::default();
    let traced = (&a * &b * &c).eval_with(&mut EvalContext::new().with_tracer(&mut counter));
    assert!(traced.approx_eq(&serial, 0.0));
    // Whatever association the model picked, two products ran and their
    // flops were observed (2 per multiplication, nothing else).
    assert!(counter.flops > 0);
    let left_flops = {
        let ab = spmmm(&a, &b, Strategy::Combined);
        flops::spmmm_flops(&a, &b) + flops::spmmm_flops(&ab, &c)
    };
    let right_flops = {
        let bc = spmmm(&b, &c, Strategy::Combined);
        flops::spmmm_flops(&b, &c) + flops::spmmm_flops(&a, &bc)
    };
    assert!(
        counter.flops == left_flops || counter.flops == right_flops,
        "traced flops {} match one association ({left_flops} / {right_flops})",
        counter.flops
    );

    // Full cache-hierarchy replay of the same tree.
    let mut h = Hierarchy::sandy_bridge();
    let _ = (&a * &b * &c).eval_with(&mut EvalContext::new().with_tracer(&mut h));
    let report = h.report();
    assert_eq!(report.flops, counter.flops, "simulator sees the same tree");
    assert!(report.l1_bytes() > 0);
}

#[test]
fn assign_to_is_the_no_allocation_assignment() {
    let a = random_fixed_per_row(200, 200, 5, 71);
    let b = random_fixed_per_row(200, 200, 5, 72);
    let reference = (&a * &b).eval();

    let mut out = CsrMatrix::new(0, 0);
    (&a * &b).assign_to(&mut out, &mut EvalContext::new());
    assert!(out.approx_eq(&reference, 0.0));
    let cap = out.capacity();
    // Re-assigning (even a different expression of the same shape)
    // reuses the buffers: capacity is already established.
    (&b * &a).assign_to(&mut out, &mut EvalContext::new());
    assert!(out.approx_eq(&(&b * &a).eval(), 0.0));
    assert_eq!(out.capacity(), cap, "no reallocation on re-assignment");

    // Sum roots stream into the kept buffers too: nnz(A)+nnz(B) fits
    // inside the capacity the product established, so no reallocation.
    (&a + &b).assign_to(&mut out, &mut EvalContext::new());
    assert!(out.approx_eq(&(&a + &b).eval(), 0.0));
    assert_eq!(out.capacity(), cap, "sum assignment reuses buffers");
}
