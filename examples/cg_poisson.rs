//! Domain example: conjugate-gradient solve of the 2D Poisson problem —
//! the FD workload the paper's matrices come from, and the CG algorithm
//! its companion study [12] benchmarks. Exercises SpMV, the expression
//! layer, the fused multi-factor chain pipeline and the FD generator.
//!
//! Run: `cargo run --release --example cg_poisson [-- grid_k]`

use blazert::expr::vector::{cg_with, norm2};
use blazert::expr::{EvalContext, Expression};
use blazert::gen::{fd_poisson_2d, fd_rhs_ones};
use blazert::sparse::SparseShape;
use blazert::util::timer::Stopwatch;

fn main() {
    let k: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let n = k * k;
    println!("2D Poisson, {k}x{k} grid (N = {n}), 5-point stencil, Dirichlet BC");

    let a = fd_poisson_2d(k);
    println!("matrix: nnz = {} ({:.2} per row)", a.nnz(), a.nnz() as f64 / n as f64);
    let b = fd_rhs_ones(k);

    // The iteration body runs through the expression layer's
    // no-allocation context path (`ap = A·p` per iteration).
    let mut ctx = EvalContext::new();
    let sw = Stopwatch::start();
    let s = cg_with(|p, ap| (&a * p).eval_into_ctx(ap, &mut ctx), &b, 1e-10, 10 * n);
    let dt = sw.seconds();
    let (x, iters, res) = (s.x, s.iterations, s.residual);

    // Verify: residual + discrete max principle.
    let mut ax = vec![0.0; n];
    (&a * &x[..]).eval_into(&mut ax);
    let r: Vec<f64> = ax.iter().zip(&b).map(|(p, q)| p - q).collect();
    let max_u = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    println!(
        "CG: {iters} iterations in {:.1} ms ({:.2} ms/iter), ||r|| = {:.2e} (reported {res:.2e})",
        dt * 1e3,
        dt * 1e3 / iters.max(1) as f64,
        norm2(&r),
    );
    // The stencil is the unscaled (4,-1) Laplacian = h^-2 * continuum
    // operator with h = 1/(k+1); for f = 1 the continuum max is ~0.0737,
    // so the discrete solution peaks near 0.0737 * (k+1)^2.
    let expect = 0.0737 * ((k + 1) * (k + 1)) as f64;
    println!("solution: max u = {max_u:.1} (continuum scaling estimate {expect:.1})");
    assert!((max_u - expect).abs() / expect < 0.05, "solution magnitude off");
    assert!(norm2(&r) < 1e-7, "residual too large");
    assert!(x.iter().all(|&v| v > 0.0), "max principle violated");

    // The fused-chain iteration: CG on the (still SPD) cubed operator
    // A³u = b. The streamed body evaluates the three-factor chain
    // A·A·A·p per iteration through the DP-lowered pipeline — neither
    // A·A nor (A·A)·A is ever materialized — and must track the
    // materialized loop (both products stored, then a plain SpMV)
    // bit-for-bit.
    let budget = 40;
    let sw = Stopwatch::start();
    let fused = cg_with(|p, ap| (&a * &a * &a * p).eval_into_ctx(ap, &mut ctx), &b, 1e-30, budget);
    let dt_fused = sw.seconds();
    let m2 = (&a * &a).eval();
    let m3 = (&m2 * &a).eval();
    let mat = cg_with(|p, ap| (&m3 * p).eval_into(ap), &b, 1e-30, budget);
    assert_eq!(fused.history.len(), mat.history.len());
    assert!(
        fused.history.iter().zip(&mat.history).all(|(f, m)| f.to_bits() == m.to_bits()),
        "fused chain CG diverged from the materialized loop"
    );
    assert!(fused.x.iter().zip(&mat.x).all(|(f, m)| f.to_bits() == m.to_bits()));
    println!(
        "chain CG (A^3 u = b, {budget} iterations, {:.1} ms): ||r|| {:.3e} -> {:.3e}, \
         residual trajectory bit-identical to the materialized loop",
        dt_fused * 1e3,
        fused.history[0],
        fused.residual
    );

    // The SpMV throughput figure (2 flops per nnz):
    let flops = 2 * a.nnz();
    let sw = Stopwatch::start();
    let reps = 50;
    let mut y = vec![0.0; n];
    let ax_expr = &a * &x[..];
    for _ in 0..reps {
        ax_expr.eval_into(&mut y);
        std::hint::black_box(&y);
    }
    let per = sw.seconds() / reps as f64;
    println!("SpMV: {:.0} MFlop/s ({:.2} GB/s effective at 20 B/nnz)",
        flops as f64 / per / 1e6,
        (a.nnz() * 20) as f64 / per / 1e9
    );
    println!("OK");
}
