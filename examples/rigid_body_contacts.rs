//! Domain example from the paper's motivation (§I): "computational
//! dynamics for rigid bodies rely on sparse matrix-matrix multiplication
//! as one of their computational kernels."
//!
//! The kernel in question is the Schur-complement (Delassus) operator of
//! a contact solver: G = J · M⁻¹ · Jᵀ, where J is the sparse contact
//! Jacobian (each contact row touches the 6 velocity DOFs of its two
//! bodies) and M⁻¹ the block-diagonal inverse mass matrix. Building G is
//! a chain of two spMMMs — exactly the paper's workload.
//!
//! Run: `cargo run --release --example rigid_body_contacts [-- n_bodies n_contacts]`

use blazert::expr::Expression;
use blazert::kernels::flops;
use blazert::sparse::{CooMatrix, CsrMatrix, SparseShape};
use blazert::util::rng::Pcg64;
use blazert::util::timer::Stopwatch;

/// Build a random contact graph: each contact couples two distinct
/// bodies; J is (3·n_contacts) × (6·n_bodies) with a dense 3x6 block per
/// incident body.
fn contact_jacobian(n_bodies: usize, n_contacts: usize, rng: &mut Pcg64) -> CsrMatrix {
    let mut j = CooMatrix::new(3 * n_contacts, 6 * n_bodies);
    for c in 0..n_contacts {
        let b1 = rng.below(n_bodies);
        let mut b2 = rng.below(n_bodies);
        while b2 == b1 {
            b2 = rng.below(n_bodies);
        }
        for (body, sign) in [(b1, 1.0), (b2, -1.0)] {
            for r in 0..3 {
                for k in 0..6 {
                    j.push(3 * c + r, 6 * body + k, sign * rng.nonzero_value());
                }
            }
        }
    }
    j.to_csr()
}

/// Block-diagonal M⁻¹: 6x6 SPD-ish blocks (diagonal here — unit inertia
/// scaling), stored sparse.
fn inv_mass(n_bodies: usize, rng: &mut Pcg64) -> CsrMatrix {
    let mut m = CsrMatrix::new(6 * n_bodies, 6 * n_bodies);
    for i in 0..6 * n_bodies {
        m.append(i, 1.0 / (0.5 + rng.f64())); // inverse masses in (2/3, 2)
        m.finalize_row();
    }
    m
}

fn main() {
    let mut args = std::env::args().skip(1);
    let n_bodies: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let n_contacts: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(6000);
    let mut rng = Pcg64::new(2013);

    println!("rigid-body contact problem: {n_bodies} bodies, {n_contacts} contacts");
    let j = contact_jacobian(n_bodies, n_contacts, &mut rng);
    let m_inv = inv_mass(n_bodies, &mut rng);
    println!(
        "J: {}x{} nnz={}  M^-1: diagonal {}x{}",
        j.rows(),
        j.cols(),
        j.nnz(),
        m_inv.rows(),
        m_inv.cols()
    );

    // G = J * M^-1 * J^T — two chained spMMM through the expression API.
    let jt = j.transpose();
    let sw = Stopwatch::start();
    let jm = (&j * &m_inv).eval();
    let g = (&jm * &jt).eval();
    let dt = sw.seconds();

    let total_flops = flops::spmmm_flops(&j, &m_inv) + flops::spmmm_flops(&jm, &jt);
    println!(
        "G = J M^-1 J^T: {}x{} nnz={} (fill {:.3}%) in {:.1} ms [{:.0} MFlop/s]",
        g.rows(),
        g.cols(),
        g.nnz(),
        100.0 * g.fill_ratio(),
        dt * 1e3,
        total_flops as f64 / dt / 1e6
    );

    // Sanity: G is symmetric (up to fp rounding) and has positive
    // diagonal (J rows are nonzero and masses positive).
    let gt = g.transpose();
    assert!(g.approx_eq(&gt, 1e-9), "G must be symmetric");
    let diag_ok = (0..g.rows()).all(|i| g.get(i, i) > 0.0);
    assert!(diag_ok, "Delassus diagonal must be positive");
    println!("verified: G symmetric, positive diagonal");

    // Contact-solver inner loop flavour: a few projected Jacobi sweeps on
    // G lambda = rhs (keeps the example honest about the downstream use).
    let n = g.rows();
    let rhs: Vec<f64> = (0..n).map(|_| rng.f64_range(-1.0, 1.0)).collect();
    let mut lambda = vec![0.0; n];
    for _ in 0..25 {
        for i in 0..n {
            let (idx, val) = g.row(i);
            let mut s = rhs[i];
            let mut dii = 1.0;
            for (&c, &v) in idx.iter().zip(val) {
                if c == i {
                    dii = v;
                } else {
                    s -= v * lambda[c];
                }
            }
            lambda[i] = (s / dii).max(0.0); // unilateral contact: λ >= 0
        }
    }
    let active = lambda.iter().filter(|&&l| l > 0.0).count();
    println!("projected Jacobi: {active}/{n} active contacts after 25 sweeps");
    println!("OK");
}
