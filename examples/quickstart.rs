//! Quickstart: the Smart-Expression-Template API on the paper's two
//! workloads — the Rust rendering of the paper's Listing 1, extended to
//! the composable expression graph with model-guided assign-time
//! scheduling.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Add `--features simd` to run the lane-unrolled numeric phase
//! (4-wide unrolled accumulate/harvest loops + software prefetch on
//! the planned refills) — results are bit-identical either way; only
//! the throughput figures should move.

use blazert::expr::{choose_strategy, EvalContext, Expression, SparseOperand};
use blazert::gen::{fd_poisson_2d, random_fixed_per_row};
use blazert::kernels::{flops, Strategy};
use blazert::model::Machine;
use blazert::sparse::{CsrMatrix, SparseShape};
use blazert::util::timer::Stopwatch;

fn main() {
    // --- Listing 1: C = A * B ------------------------------------------
    // blaze::CompressedMatrix<double,rowMajor> A, B, C;
    // C = A * B;
    let a = fd_poisson_2d(64); // 4096 x 4096 five-band FD matrix
    let b = fd_poisson_2d(64);
    let sw = Stopwatch::start();
    let c = (&a * &b).eval(); // assign-time, model-guided kernel selection
    let dt = sw.seconds();
    println!(
        "FD:      ({}x{}, nnz={}) * (nnz={}) -> nnz={} in {:.2} ms  [{:.0} MFlop/s]",
        a.rows(),
        a.cols(),
        a.nnz(),
        b.nnz(),
        c.nnz(),
        dt * 1e3,
        flops::spmmm_flops(&a, &b) as f64 / dt / 1e6
    );

    // --- The model's assign-time choices -------------------------------
    let machine = Machine::sandy_bridge_i7_2600();
    let ar = random_fixed_per_row(4096, 4096, 5, 1);
    let br = random_fixed_per_row(4096, 4096, 5, 2);
    println!(
        "model:   FD picks {}, random picks {} (bandwidth-model roofline)",
        choose_strategy(&machine, &a, &b).name(),
        choose_strategy(&machine, &ar, &br).name()
    );

    // --- Explicit strategy via the uniform EvalContext -----------------
    for strategy in [Strategy::MinMax, Strategy::Sort, Strategy::Combined] {
        let sw = Stopwatch::start();
        let cr = (&ar * &br).eval_with(&mut EvalContext::using(strategy));
        let dt = sw.seconds();
        println!(
            "random:  {:<18} nnz={} in {:.2} ms  [{:.0} MFlop/s]",
            strategy.name(),
            cr.nnz(),
            dt * 1e3,
            flops::spmmm_flops(&ar, &br) as f64 / dt / 1e6
        );
    }

    // --- Composable graphs: no intermediate .eval() calls --------------
    let sw = Stopwatch::start();
    let g = (2.0 * (&a * &b) + &a).eval();
    let abc = (&a * &b * &a).eval(); // association order chosen by the model
    let dt = sw.seconds();
    println!(
        "graph:   2*(A*B)+A nnz={}, A*B*A nnz={} in {:.2} ms total",
        g.nnz(),
        abc.nnz(),
        dt * 1e3
    );

    // --- Mixed storage orders: conversion inserted automatically -------
    let b_csc = blazert::sparse::convert::csr_to_csc(&br);
    let c_mixed = (&ar * &b_csc).eval();
    println!("mixed:   CSR x CSC handled by assign-time conversion, nnz={}", c_mixed.nnz());

    // --- Other expressions ---------------------------------------------
    let sum = (&a + &b).eval();
    let scaled = (0.5 * &a).eval();
    let y = (&a * &vec![1.0; a.cols()]).eval();
    println!(
        "expr:    A+B nnz={}, 0.5*A nnz={}, A*1 row-sum range [{:.1}, {:.1}]",
        sum.nnz(),
        scaled.nnz(),
        y.iter().cloned().fold(f64::INFINITY, f64::min),
        y.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    );

    // --- Fused pipelines: A*B*x never materializes A*B -----------------
    let xv = vec![1.0; b.cols()];
    let sw = Stopwatch::start();
    let yf = (&a * &b * &xv).eval(); // fused spMMM->SpMV, model-arbitrated
    let dt = sw.seconds();
    // A declared fanout > 1 tells the arbitration the chain product has
    // other consumers; a large one forces the materialized fallback —
    // which must agree with the fused path to the last bit.
    let y_mat = (&a * &b * &xv).with_fanout(1024).eval();
    let identical = yf.iter().zip(&y_mat).all(|(p, q)| p.to_bits() == q.to_bits());
    let y_tail = (&a * &b * &xv + &yf).eval(); // the A*B*x + y form
    println!(
        "fused:   A*B*x in {:.2} ms, no intermediate; bits match fallback: {}, |y+t| max {:.1}",
        dt * 1e3,
        identical,
        y_tail.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    );

    // --- Streamed multi-factor chains: A*B*A*x, zero intermediates -----
    // The chain DP extends fusion through every hop: leading products
    // stream row-by-row into the fused root, so neither A*B nor
    // (A*B)*A is ever stored — and the result is still bit-identical
    // to materializing every hop.
    let sw = Stopwatch::start();
    let y3 = (&a * &b * &a * &xv).eval();
    let dt = sw.seconds();
    let m2 = (&a * &b).eval();
    let m3 = (&m2 * &a).eval();
    let y3_mat = (&m3 * &xv).eval();
    let identical3 = y3.iter().zip(&y3_mat).all(|(p, q)| p.to_bits() == q.to_bits());
    println!(
        "chain:   A*B*A*x streamed in {:.2} ms, no intermediates; bits match materialized: {}",
        dt * 1e3,
        identical3
    );

    // --- No-allocation assignment: C is reused across evaluations ------
    let mut out = CsrMatrix::new(0, 0);
    (&ar * &br).assign_to(&mut out, &mut EvalContext::new());
    let cap = out.capacity();
    (&ar * &br).assign_to(&mut out, &mut EvalContext::new());
    println!(
        "assign:  C reused across assignments (capacity {} -> {}, no realloc)",
        cap,
        out.capacity()
    );

    // The estimate the paper's single-allocation store relies on:
    let est = flops::nnz_estimate(&ar, &br);
    println!("alloc:   nnz estimate {est} >= actual {} (never underestimates)", out.nnz());

    // --- Parallel evaluation through the same context ------------------
    let sw = Stopwatch::start();
    let cp = (&ar * &br).eval_with(&mut EvalContext::new().with_threads(4));
    let dt = sw.seconds();
    println!(
        "threads: 4-way parallel eval nnz={} in {:.2} ms  [{:.0} MFlop/s]",
        cp.nnz(),
        dt * 1e3,
        flops::spmmm_flops(&ar, &br) as f64 / dt / 1e6
    );
}
