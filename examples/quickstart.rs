//! Quickstart: the Smart-Expression-Template API on the paper's two
//! workloads — the Rust rendering of the paper's Listing 1.
//!
//! Run: `cargo run --release --example quickstart`

use blazert::expr::Expression;
use blazert::gen::{fd_poisson_2d, random_fixed_per_row};
use blazert::kernels::{flops, Strategy};
use blazert::sparse::SparseShape;
use blazert::util::timer::Stopwatch;

fn main() {
    // --- Listing 1: C = A * B ------------------------------------------
    // blaze::CompressedMatrix<double,rowMajor> A, B, C;
    // C = A * B;
    let a = fd_poisson_2d(64); // 4096 x 4096 five-band FD matrix
    let b = fd_poisson_2d(64);
    let sw = Stopwatch::start();
    let c = (&a * &b).eval(); // assign-time kernel selection: Combined
    let dt = sw.seconds();
    println!(
        "FD:      ({}x{}, nnz={}) * (nnz={}) -> nnz={} in {:.2} ms  [{:.0} MFlop/s]",
        a.rows(),
        a.cols(),
        a.nnz(),
        b.nnz(),
        c.nnz(),
        dt * 1e3,
        flops::spmmm_flops(&a, &b) as f64 / dt / 1e6
    );

    // --- Random workload, explicit strategy ----------------------------
    let ar = random_fixed_per_row(4096, 4096, 5, 1);
    let br = random_fixed_per_row(4096, 4096, 5, 2);
    for strategy in [Strategy::MinMax, Strategy::Sort, Strategy::Combined] {
        let sw = Stopwatch::start();
        let cr = (&ar * &br).eval_with(strategy);
        let dt = sw.seconds();
        println!(
            "random:  {:<18} nnz={} in {:.2} ms  [{:.0} MFlop/s]",
            strategy.name(),
            cr.nnz(),
            dt * 1e3,
            flops::spmmm_flops(&ar, &br) as f64 / dt / 1e6
        );
    }

    // --- Mixed storage orders: conversion inserted automatically -------
    let b_csc = blazert::sparse::convert::csr_to_csc(&br);
    let c_mixed = (&ar * &b_csc).eval();
    println!("mixed:   CSR x CSC handled by assign-time conversion, nnz={}", c_mixed.nnz());

    // --- Other expressions ---------------------------------------------
    let sum = (&a + &b).eval();
    let scaled = (0.5 * &a).eval();
    let y = (&a * &vec![1.0; a.cols()]).eval();
    println!(
        "expr:    A+B nnz={}, 0.5*A nnz={}, A*1 row-sum range [{:.1}, {:.1}]",
        sum.nnz(),
        scaled.nnz(),
        y.iter().cloned().fold(f64::INFINITY, f64::min),
        y.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    );

    // The estimate the paper's single-allocation store relies on:
    let est = flops::nnz_estimate(&ar, &br);
    let real = {
        let c = (&ar * &br).eval();
        c.nnz()
    };
    println!("alloc:   nnz estimate {est} >= actual {real} (never underestimates)");
}
