//! Multi-tenant service walkthrough: weighted-fair scheduling,
//! admission-control backpressure, crash recovery through an expiring
//! lease, per-tenant plan-store quotas, and a small saturation batch
//! with power-law job sizes.
//!
//! Run: `cargo run --release --example multi_tenant`

use blazert::exec::{default_machine, ExecPool, Partition};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::{spmmm, Strategy};
use blazert::service::{
    JobService, PlanQuotas, SaturationBench, SaturationConfig, ServiceConfig,
};
use blazert::sparse::SparseShape;

fn main() {
    // --- Weighted fairness + backpressure ------------------------------
    // Two tenants share one service: `prio` carries weight 3, `batch`
    // weight 1, and `batch`'s queue is deliberately undersized.
    let svc: JobService<usize> = JobService::new(ServiceConfig::default());
    let prio = svc.register_tenant("prio", 3, 8);
    let batch = svc.register_tenant("batch", 1, 2);
    for job in 0..6 {
        svc.submit(prio, job).unwrap();
    }
    svc.submit(batch, 0).unwrap();
    svc.submit(batch, 1).unwrap();
    // The third submit hits the depth-2 queue: admission control turns
    // it away with a reason instead of growing without bound.
    let refused = svc.submit(batch, 2).unwrap_err();
    println!("backpressure: {refused}");

    // Draining interleaves 3:1 — the light tenant is served inside
    // every weight window, never starved to the end of the batch.
    let (a, b) = operand_pair(Workload::RandomFixed5, 96, 1);
    let mut order = Vec::new();
    while let Some(claim) = svc.claim() {
        let c = spmmm(&a, &b, Strategy::Combined);
        order.push((claim.tenant, c.nnz()));
        svc.complete(claim.token);
    }
    let tags: Vec<&str> =
        order.iter().map(|&(t, _)| if t == prio { "prio" } else { "batch" }).collect();
    println!("wrr order:    {}", tags.join(" "));

    // --- Crash recovery through the lease ------------------------------
    // A worker claims a job and dies; its lease expires (the example
    // advances the service clock instead of sleeping), the next claim
    // reclaims the job, and the ghost completion is fenced off.
    let flaky: JobService<usize> = JobService::new(ServiceConfig {
        lease_timeout_ns: 1_000_000,
        max_attempts: 3,
    });
    let t = flaky.register_tenant("acme", 1, 4);
    flaky.submit(t, 7).unwrap();
    let doomed = flaky.claim().unwrap();
    flaky.advance(2_000_000); // the worker never comes back
    let retry = flaky.claim().unwrap();
    println!(
        "recovery:     job {} reclaimed on attempt {} (stale ghost fenced: {})",
        retry.job,
        retry.attempt,
        flaky.complete(doomed.token).is_none()
    );
    flaky.complete(retry.token);
    let c = flaky.counters();
    println!(
        "ledger:       completed={} requeued={} lost={} duplicates_fenced={}",
        c.completed, c.requeued, c.lost, c.stale_results
    );

    // --- Per-tenant plan quotas ----------------------------------------
    // Each tenant's plan store lives in its own directory under its
    // own byte budget; eviction can only ever touch the owner.
    let dir = std::env::temp_dir().join("blazert_multi_tenant_example");
    let _ = std::fs::remove_dir_all(&dir);
    let quotas = PlanQuotas::open(&dir, 1 << 20);
    let pool = ExecPool::new(4);
    let (fa, fb) = operand_pair(Workload::FiveBandFd, 300, 11);
    for name in ["prio", "batch"] {
        let plans = quotas.tenant(name, None).expect("tenant store opens");
        pool.with_local(|ws| {
            plans.cache.get_or_build(default_machine(), ws, &fa, &fb, 1, Partition::Flops);
        });
        println!(
            "quota:        tenant {name:<5} -> {} ({} plan(s), budget {} KiB)",
            plans.warm.store.dir().display(),
            plans.warm.store.len(),
            plans.quota_bytes >> 10
        );
    }
    std::fs::remove_dir_all(&dir).ok();

    // --- Saturation: 200 tenants, power-law sizes ----------------------
    let bench = SaturationBench::new(&SaturationConfig {
        tenants: 200,
        jobs_per_tenant: 3,
        queue_depth: 3,
        generator: Workload::RandomFixed5,
        n_min: 32,
        n_max: 256,
        alpha: 1.1,
        seed: 42,
    });
    bench.presize(&pool, 4);
    for phase in ["cold", "warm"] {
        let rep = bench.run_batch(&pool, 4);
        println!(
            "{phase:<5} batch:   {} jobs in {:.1} ms  p50 {:.2} ms  p99 {:.2} ms  \
             {:.0} jobs/s  fairness {:.3}  lost {}  dup {}  rejected {}",
            rep.jobs_completed,
            rep.seconds * 1e3,
            rep.p50_latency_s * 1e3,
            rep.p99_latency_s * 1e3,
            rep.throughput_jps,
            rep.fairness_index,
            rep.lost_jobs,
            rep.duplicate_jobs,
            rep.rejected_jobs
        );
    }
}
