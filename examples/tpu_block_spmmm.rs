//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! Proves all layers compose: the L3 Rust coordinator converts the
//! paper's FD and random workloads to block-sparse form, schedules
//! block-Gustavson wavefronts, and executes every flop through the AOT
//! artifact (L2 JAX graph wrapping the L1 Pallas tile kernel) on the
//! PJRT CPU client — no Python anywhere in the process. Results are
//! verified against the paper's scalar Combined kernel, and the run
//! reports throughput plus scheduling/batching telemetry.
//!
//! Requires `make artifacts` (skips with a notice otherwise — CI safety).
//!
//! Run: `cargo run --release --example tpu_block_spmmm`

use blazert::bsr::{bsr_spmmm, BsrMatrix, NativeBackend, TileBackend};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::{spmmm, Strategy};
use blazert::runtime::{Runtime, TileEngine};
use blazert::sparse::{DenseMatrix, SparseShape};
use blazert::util::table::Table;
use blazert::util::timer::Stopwatch;

fn run_case<B: TileBackend>(
    name: &str,
    workload: Workload,
    n: usize,
    tile: usize,
    backend: &mut B,
    table: &mut Table,
) -> anyhow::Result<()> {
    let (a, b) = operand_pair(workload, n, 99);
    let ab = BsrMatrix::from_csr(&a, tile);
    let bb = BsrMatrix::from_csr(&b, tile);

    let sw = Stopwatch::start();
    let c = bsr_spmmm(&ab, &bb, backend)?;
    let secs = sw.seconds();

    // Verify against the paper's scalar kernel (f32 tile tolerance).
    let reference = spmmm(&a, &b, Strategy::Combined);
    let d1 = DenseMatrix::from_csr(&c.to_csr());
    let d2 = DenseMatrix::from_csr(&reference);
    let rel = d1.max_abs_diff(&d2) / d2.frobenius().max(1.0);
    assert!(rel < 1e-5, "{name}: rel err {rel}");

    let flops = spmmm_flops(&a, &b);
    table.row([
        name.to_string(),
        a.rows().to_string(),
        ab.nblocks().to_string(),
        format!("{:.1}%", 100.0 * ab.fill_in_ratio(a.nnz())),
        format!("{:.1}", secs * 1e3),
        format!("{:.1}", flops as f64 / secs / 1e6),
        format!("{rel:.1e}"),
    ]);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("=== end-to-end: BSR block-Gustavson over the AOT JAX/Pallas artifact ===\n");
    let mut table = Table::new([
        "backend+workload", "N", "A blocks", "fill-in", "ms", "MFlop/s", "rel err",
    ]);

    if !Runtime::artifacts_available() {
        eprintln!("artifacts/ not found — run `make artifacts` first.");
        eprintln!("falling back to the native backend so the example still demonstrates");
        eprintln!("the BSR scheduler:");
        let mut nb = NativeBackend { tile: 32 };
        run_case("native FD", Workload::FiveBandFd, 4096, 32, &mut nb, &mut table)?;
        println!("{}", table.render());
        return Ok(());
    }

    let mut engine = TileEngine::load_default()?;
    println!(
        "PJRT platform: {}   artifact geometry: tile={} batch={}\n",
        engine.platform(),
        engine.tile,
        engine.batch
    );
    let tile = engine.tile;

    // XLA path on both paper workloads.
    run_case("XLA FD", Workload::FiveBandFd, 4096, tile, &mut engine, &mut table)?;
    let (calls_fd, slots_fd, padded_fd) = (engine.calls, engine.slots, engine.padded_slots);
    run_case("XLA random", Workload::RandomFixed5, 2048, tile, &mut engine, &mut table)?;

    // Native backend for comparison (same schedule, Rust tile kernels).
    let mut nb = NativeBackend { tile };
    run_case("native FD", Workload::FiveBandFd, 4096, tile, &mut nb, &mut table)?;
    run_case("native random", Workload::RandomFixed5, 2048, tile, &mut nb, &mut table)?;

    println!("{}", table.render());
    println!(
        "scheduler telemetry (FD run): {} backend calls, {} slots, {} padded ({:.0}% waste)",
        calls_fd,
        slots_fd,
        padded_fd,
        100.0 * padded_fd as f64 / slots_fd.max(1) as f64
    );
    println!(
        "\nall layers verified: L3 scheduling -> PJRT -> L2 HLO -> L1 Pallas tile kernel"
    );
    println!("(on real TPU hardware the same kernel recompiles without interpret=True;");
    println!(" perf there is estimated from VMEM/MXU structure — DESIGN.md §Hardware-Adaptation)");
    Ok(())
}
