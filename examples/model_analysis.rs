//! The paper's headline method, end to end: *model-guided* performance
//! analysis of the spMMM kernels.
//!
//! For each kernel/workload the example (1) replays the exact kernel
//! code path against the simulated Sandy Bridge i7-2600 cache hierarchy,
//! (2) derives the per-data-path code balances and light-speed ceilings
//! (P = min(P_max, b/B_c) — §IV-A), (3) measures wall-clock MFlop/s on
//! this host, and (4) reports measured-vs-model efficiency.
//!
//! Run: `cargo run --release --example model_analysis`

use blazert::blazemark::{measure, BenchConfig};
use blazert::gen::{operand_pair, Workload};
use blazert::kernels::flops::spmmm_flops;
use blazert::kernels::gustavson::pure_row_major;
use blazert::kernels::{spmmm, spmmm_traced, NullTracer, Strategy};
use blazert::model::{balance::GUSTAVSON_INNER_BALANCE, predict, Machine};
use blazert::simulator::Hierarchy;
use blazert::sparse::SparseShape;
use blazert::util::table::Table;

fn main() {
    let machine = Machine::sandy_bridge_i7_2600();
    println!("machine model: {}", machine.name);
    println!(
        "paper's analytic limits at {} B/Flop: L1 {:.0} MFlop/s, memory {:.0} MFlop/s\n",
        GUSTAVSON_INNER_BALANCE,
        blazert::model::lightspeed(&machine, Some(0), GUSTAVSON_INNER_BALANCE) / 1e6,
        blazert::model::lightspeed(&machine, None, GUSTAVSON_INNER_BALANCE) / 1e6,
    );

    let cfg = BenchConfig::quick();
    let mut table = Table::new([
        "workload", "N", "kernel", "mem B/F", "model MF/s", "measured MF/s", "efficiency",
    ]);

    for workload in [Workload::FiveBandFd, Workload::RandomFixed5] {
        // One in-cache size, one beyond-LLC size (the two regimes of
        // Figures 2/3).
        for n in [4096usize, 147456] {
            let (a, b) = operand_pair(workload, n, 7);
            let flops = spmmm_flops(&a, &b);

            // Pure computation.
            let mut h = Hierarchy::of_machine(&machine);
            let _ = pure_row_major(&a, &b, &mut h);
            let p = predict(&machine, &h.report());
            let m = measure(&cfg, || {
                std::hint::black_box(pure_row_major(&a, &b, &mut NullTracer));
            });
            let meas = m.mflops(flops);
            table.row([
                workload.tag().to_string(),
                a.rows().to_string(),
                "pure row-major".to_string(),
                format!("{:.2}", h.report().mem_balance()),
                format!("{:.0}", p.predicted / 1e6),
                format!("{meas:.0}"),
                format!("{:.0}%", 100.0 * meas * 1e6 / p.predicted),
            ]);

            // Full kernel (Combined).
            let mut h2 = Hierarchy::of_machine(&machine);
            let _ = spmmm_traced(&a, &b, Strategy::Combined, &mut h2);
            let p2 = predict(&machine, &h2.report());
            let m2 = measure(&cfg, || {
                std::hint::black_box(spmmm(&a, &b, Strategy::Combined));
            });
            let meas2 = m2.mflops(flops);
            table.row([
                workload.tag().to_string(),
                a.rows().to_string(),
                "Combined spMMM".to_string(),
                format!("{:.2}", h2.report().mem_balance()),
                format!("{:.0}", p2.predicted / 1e6),
                format!("{meas2:.0}"),
                format!("{:.0}%", 100.0 * meas2 * 1e6 / p2.predicted),
            ]);
        }
    }
    println!("{}", table.render());
    println!("notes:");
    println!("  * 'model MF/s' is the light speed on the *simulated i7-2600*; 'measured'");
    println!("    is wall-clock on this host — efficiency > 100% simply means this CPU");
    println!("    outruns a 2011 Sandy Bridge. The paper's claim to check is the SHAPE:");
    println!("    in-cache sizes sit near the L1/L2 ceilings, out-of-cache sizes near the");
    println!("    memory ceiling, and the random workload falls below its FD counterpart.");
}
