"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps tile sizes, batch sizes and value distributions; every
case asserts allclose between the interpret-mode Pallas kernel and
``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.tile_matmul import (
    BATCH,
    TILE,
    batched_tile_matmul,
    grouped_tile_matmul,
    mxu_utilization,
    vmem_bytes,
)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestBatchedTileMatmul:
    def test_artifact_geometry(self):
        a = rand(0, (BATCH, TILE, TILE))
        b = rand(1, (BATCH, TILE, TILE))
        acc = rand(2, (BATCH, TILE, TILE))
        out = batched_tile_matmul(a, b, acc)
        expect = ref.batched_tile_matmul_ref(a, b, acc)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)

    def test_zero_accumulator(self):
        a = rand(3, (4, 8, 8))
        b = rand(4, (4, 8, 8))
        acc = jnp.zeros((4, 8, 8), jnp.float32)
        out = batched_tile_matmul(a, b, acc)
        np.testing.assert_allclose(
            out, jnp.einsum("bij,bjk->bik", a, b), rtol=1e-5, atol=1e-6
        )

    def test_identity_tiles(self):
        eye = jnp.broadcast_to(jnp.eye(16, dtype=jnp.float32), (3, 16, 16))
        x = rand(5, (3, 16, 16))
        acc = jnp.zeros_like(x)
        np.testing.assert_allclose(
            batched_tile_matmul(eye, x, acc), x, rtol=1e-6, atol=1e-6
        )

    def test_accumulation_chains(self):
        # Two chained calls == one call on the summed product.
        a1, b1 = rand(6, (2, 8, 8)), rand(7, (2, 8, 8))
        a2, b2 = rand(8, (2, 8, 8)), rand(9, (2, 8, 8))
        acc = jnp.zeros((2, 8, 8), jnp.float32)
        step1 = batched_tile_matmul(a1, b1, acc)
        step2 = batched_tile_matmul(a2, b2, step1)
        expect = jnp.einsum("bij,bjk->bik", a1, b1) + jnp.einsum(
            "bij,bjk->bik", a2, b2
        )
        np.testing.assert_allclose(step2, expect, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.integers(1, 8),
        tile=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_hypothesis_sweep(self, batch, tile, seed, scale):
        a = rand(seed, (batch, tile, tile), scale)
        b = rand(seed + 1, (batch, tile, tile), scale)
        acc = rand(seed + 2, (batch, tile, tile), scale)
        out = batched_tile_matmul(a, b, acc)
        expect = ref.batched_tile_matmul_ref(a, b, acc)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4 * scale * scale)


class TestGroupedTileMatmul:
    def test_matches_ref(self):
        a = rand(10, (3, 5, 8, 8))
        b = rand(11, (3, 5, 8, 8))
        out = grouped_tile_matmul(a, b)
        np.testing.assert_allclose(
            out, ref.grouped_tile_matmul_ref(a, b), rtol=1e-4, atol=1e-4
        )

    def test_single_k_is_plain_product(self):
        a = rand(12, (2, 1, 8, 8))
        b = rand(13, (2, 1, 8, 8))
        out = grouped_tile_matmul(a, b)
        np.testing.assert_allclose(
            out[:, :, :], jnp.einsum("gkij,gkjl->gil", a, b), rtol=1e-5, atol=1e-5
        )

    def test_zero_blocks_padding(self):
        # Padding tail entries with zero tiles must not change the sum —
        # the L3 scheduler relies on this to fill fixed-size batches.
        a = rand(14, (1, 4, 8, 8))
        b = rand(15, (1, 4, 8, 8))
        a_pad = jnp.concatenate([a, jnp.zeros((1, 2, 8, 8), jnp.float32)], axis=1)
        b_pad = jnp.concatenate([b, rand(16, (1, 2, 8, 8))], axis=1)
        np.testing.assert_allclose(
            grouped_tile_matmul(a_pad, b_pad),
            grouped_tile_matmul(a, b),
            rtol=1e-4,
            atol=1e-5,
        )

    @settings(max_examples=15, deadline=None)
    @given(
        g=st.integers(1, 4),
        k=st.integers(1, 6),
        tile=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, g, k, tile, seed):
        a = rand(seed, (g, k, tile, tile))
        b = rand(seed + 1, (g, k, tile, tile))
        np.testing.assert_allclose(
            grouped_tile_matmul(a, b),
            ref.grouped_tile_matmul_ref(a, b),
            rtol=1e-4,
            atol=1e-4,
        )


class TestKernelStructure:
    def test_vmem_fits(self):
        # One grid step (with double-buffering headroom) must fit VMEM.
        assert vmem_bytes() < 16 * 1024 * 1024

    def test_mxu_utilization_monotone(self):
        assert mxu_utilization(32) < mxu_utilization(64) < mxu_utilization(128)
        assert mxu_utilization(128) == 1.0
        assert mxu_utilization(256) == 1.0  # capped

    def test_lowering_contains_no_custom_call(self):
        # interpret=True must lower to plain HLO the CPU PJRT can run:
        # no Mosaic custom-calls in the module text.
        a = jax.ShapeDtypeStruct((2, 8, 8), jnp.float32)
        lowered = jax.jit(lambda x, y, z: batched_tile_matmul(x, y, z)).lower(a, a, a)
        text = lowered.as_text()
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()
