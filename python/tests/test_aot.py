"""AOT pipeline: HLO text generation + manifest round-trip."""

import os

from compile import aot, model


def test_export_all_writes_artifacts(tmp_path):
    outdir = str(tmp_path / "artifacts")
    lines = aot.export_all(outdir, verbose=False)
    assert len(lines) == len(model.entry_points())
    for name in model.entry_points():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        # HLO text module header and an entry computation.
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # Tuple return (rust side unwraps with to_tuple1).
        assert "tuple" in text.lower(), name
    manifest = open(os.path.join(outdir, "manifest.txt")).read().strip().splitlines()
    assert len(manifest) == len(lines)
    for line in manifest:
        fields = dict(kv.split("=", 1) for kv in line.split())
        assert {"name", "file", "dtype", "args", "tile", "batch"} <= set(fields)
        assert fields["dtype"] == "f32"


def test_hlo_text_has_no_custom_calls(tmp_path):
    outdir = str(tmp_path / "a")
    aot.export_all(outdir, verbose=False)
    for name in model.entry_points():
        text = open(os.path.join(outdir, f"{name}.hlo.txt")).read()
        assert "custom-call" not in text, f"{name} must run on CPU PJRT"


def test_shape_tag():
    import jax
    import jax.numpy as jnp

    s = jax.ShapeDtypeStruct((64, 32, 32), jnp.float32)
    assert aot.shape_tag(s) == "64x32x32"
