"""L2 model entry points: shape table, jit-ability, numeric sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def test_entry_point_table_complete():
    eps = model.entry_points()
    assert set(eps) == {"tile_mma", "tile_group_mma", "dense_mm"}
    for name, (fn, args) in eps.items():
        assert callable(fn), name
        assert all(isinstance(a, jax.ShapeDtypeStruct) for a in args), name


def test_tile_mma_shapes_match_manifest_geometry():
    _, args = model.entry_points()["tile_mma"]
    assert args[0].shape == (model.BATCH, model.TILE, model.TILE)
    assert all(a.shape == args[0].shape for a in args)
    assert all(a.dtype == jnp.float32 for a in args)


def test_dense_mm_numeric():
    a = jax.random.normal(jax.random.PRNGKey(0), (32, 32), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 32), jnp.float32)
    np.testing.assert_allclose(model.dense_mm(a, b), a @ b, rtol=1e-5, atol=1e-5)


def test_tile_group_mma_matches_ref():
    a = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 8, 8), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 8, 8), jnp.float32)
    np.testing.assert_allclose(
        model.tile_group_mma(a, b),
        ref.grouped_tile_matmul_ref(a, b),
        rtol=1e-4,
        atol=1e-4,
    )


def test_entry_points_lower_without_error():
    for name, (fn, args) in model.entry_points().items():
        lowered = jax.jit(fn).lower(*args)
        assert lowered.as_text(), name
