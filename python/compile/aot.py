"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

HLO text (not ``lowered.compile()`` output and not serialized
``HloModuleProto`` bytes) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
on the Rust side reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage::

    python -m compile.aot --outdir ../artifacts

Writes one ``<name>.hlo.txt`` per entry point plus ``manifest.txt`` with
the call geometry the Rust runtime validates against::

    name=tile_mma file=tile_mma.hlo.txt dtype=f32 args=64x32x32,64x32x32,64x32x32
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True; the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_tag(s: jax.ShapeDtypeStruct) -> str:
    return "x".join(str(d) for d in s.shape)


def export_all(outdir: str, verbose: bool = True) -> list[str]:
    os.makedirs(outdir, exist_ok=True)
    manifest_lines = []
    for name, (fn, args) in model.entry_points().items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        arg_tags = ",".join(shape_tag(a) for a in args)
        manifest_lines.append(
            f"name={name} file={fname} dtype=f32 args={arg_tags} "
            f"tile={model.TILE} batch={model.BATCH} "
            f"groups={model.GROUPS} group_k={model.GROUP_K} dense_n={model.DENSE_N}"
        )
        if verbose:
            print(f"wrote {path} ({len(text)} chars)")
    manifest = os.path.join(outdir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    if verbose:
        print(f"wrote {manifest}")
    return manifest_lines


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--outdir", default="../artifacts", help="artifact directory")
    args = p.parse_args()
    export_all(args.outdir)


if __name__ == "__main__":
    main()
