"""Layer 1 — the Pallas tile multiply-accumulate kernel.

TPU adaptation of the paper's hot loop (DESIGN.md §Hardware-Adaptation):
the scalar Gustavson update ``temp[j] += a_ik * b_kj`` becomes a dense
(T, T) tile product accumulated into a dense accumulator tile — the
"dense temporary row" of the paper at block granularity, sized for VMEM
and shaped for the MXU systolic array.

The Rust coordinator (L3) performs Gustavson over *block* indices of BSR
operands and streams batches of (A-tile, B-tile, C-accumulator-tile)
triples through this kernel; the batch dimension is the Pallas grid, so
on a real TPU the HBM->VMEM pipeline double-buffers tile fetches while
the MXU computes.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which both the pytest
oracle checks and the Rust runtime execute. On a real TPU the same code
compiles natively by dropping the flag.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default artifact geometry: 32x32 f32 tiles in batches of 64.
#   VMEM per grid step: 3 tiles x 32*32 x 4 B = 12 kB  (<< 16 MB VMEM)
#   MXU: a 32x32 f32 matmul maps onto 128x128 MXU quarter-tiles; T=128
#   would fill the MXU fully but quadruples the zero-padding waste of
#   sparse blocks - see the tile-size ablation in EXPERIMENTS.md.
TILE = 32
BATCH = 64


def _mma_kernel(a_ref, b_ref, acc_ref, o_ref):
    """One grid step: o = acc + a @ b for a single (1, T, T) block."""
    a = a_ref[0]
    b = b_ref[0]
    acc = acc_ref[0]
    o_ref[0] = acc + jnp.dot(a, b, preferred_element_type=o_ref.dtype)


@partial(jax.jit, static_argnames=())
def batched_tile_matmul(a, b, acc):
    """Batched tile multiply-accumulate: ``out[i] = acc[i] + a[i] @ b[i]``.

    Args:
      a:   f32[B, T, T] left tiles.
      b:   f32[B, T, T] right tiles.
      acc: f32[B, T, T] accumulator tiles.

    Returns:
      f32[B, T, T].
    """
    batch, t, t2 = a.shape
    assert t == t2 and b.shape == a.shape and acc.shape == a.shape
    block = pl.BlockSpec((1, t, t), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _mma_kernel,
        grid=(batch,),
        in_specs=[block, block, block],
        out_specs=block,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b, acc)


def _reduce_kernel(a_ref, b_ref, o_ref):
    """Grid step (i, k): accumulate a[i,k] @ b[i,k] into o[i].

    The k axis is sequential (innermost grid dimension), so the output
    block is revisited and accumulated in place - the standard Pallas
    reduction idiom. On TPU the accumulator tile stays resident in VMEM
    across the k steps.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[0] = jnp.zeros_like(o_ref[0])

    o_ref[0] += jnp.dot(
        a_ref[0, 0], b_ref[0, 0], preferred_element_type=o_ref.dtype
    )


@partial(jax.jit, static_argnames=())
def grouped_tile_matmul(a, b):
    """Grouped product: ``out[i] = sum_k a[i, k] @ b[i, k]``.

    This is one full block-row x block-column Gustavson group in a single
    call: the L3 scheduler packs the K partial products of one output
    block into the k axis.

    Args:
      a: f32[G, K, T, T].
      b: f32[G, K, T, T].

    Returns:
      f32[G, T, T].
    """
    g, k, t, t2 = a.shape
    assert t == t2 and b.shape == a.shape
    in_block = pl.BlockSpec((1, 1, t, t), lambda i, j: (i, j, 0, 0))
    out_block = pl.BlockSpec((1, t, t), lambda i, j: (i, 0, 0))
    return pl.pallas_call(
        _reduce_kernel,
        grid=(g, k),
        in_specs=[in_block, in_block],
        out_specs=out_block,
        out_shape=jax.ShapeDtypeStruct((g, t, t), a.dtype),
        interpret=True,
    )(a, b)


def vmem_bytes(tile: int = TILE, dtype_bytes: int = 4, buffers: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (tiles + double-buffer)."""
    return buffers * tile * tile * dtype_bytes


def mxu_utilization(tile: int = TILE, mxu: int = 128) -> float:
    """Fraction of MXU lanes a TxT f32 tile product can keep busy.

    The MXU is a 128x128 systolic array; a T<128 tile uses (T/128)^2 of
    it per pass (ignoring pipelining of multiple tiles, which Mosaic
    performs for batched grids).
    """
    frac = min(tile, mxu) / mxu
    return frac * frac
