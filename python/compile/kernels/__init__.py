# L1: Pallas kernel(s) for the paper's compute hot-spot.
from . import ref, tile_matmul

__all__ = ["ref", "tile_matmul"]
