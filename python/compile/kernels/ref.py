"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest asserts kernel == ref to float tolerance)."""

import jax.numpy as jnp


def batched_tile_matmul_ref(a, b, acc):
    """out[i] = acc[i] + a[i] @ b[i] (einsum form, no Pallas)."""
    return acc + jnp.einsum("bij,bjk->bik", a, b)


def grouped_tile_matmul_ref(a, b):
    """out[g] = sum_k a[g,k] @ b[g,k]."""
    return jnp.einsum("gkij,gkjl->gil", a, b)


def dense_matmul_ref(a, b):
    """Plain dense product."""
    return jnp.matmul(a, b)
