"""Layer 2 — the JAX compute graph around the Pallas kernel.

The paper's system is a numerics library, so the L2 "model" is the set of
jitted compute entry points the Rust coordinator calls through PJRT:

* ``tile_mma``       — batched tile multiply-accumulate (the BSR
                       block-Gustavson inner step; wraps the L1 Pallas
                       kernel so it lowers into the same HLO module);
* ``tile_group_mma`` — whole block-row reduction groups (one output tile
                       per group) for the grouped scheduler variant;
* ``dense_mm``       — a plain dense product used by the runtime's
                       verification path on densified small operands.

Everything here executes at build time only; ``aot.py`` lowers each entry
with fixed shapes to HLO text under ``artifacts/``, and the Rust runtime
loads those. Python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import ref, tile_matmul

# Artifact geometry (keep in sync with rust/src/runtime/tile_engine.rs,
# which reads it from the manifest at load time).
TILE = tile_matmul.TILE
BATCH = tile_matmul.BATCH
GROUPS = 16
GROUP_K = 8
DENSE_N = 256


def tile_mma(a, b, acc):
    """Batched tile multiply-accumulate via the Pallas kernel."""
    return tile_matmul.batched_tile_matmul(a, b, acc)


def tile_group_mma(a, b):
    """Grouped block-row reduction via the Pallas kernel."""
    return tile_matmul.grouped_tile_matmul(a, b)


def dense_mm(a, b):
    """Dense f32 product (verification path)."""
    return ref.dense_matmul_ref(a, b)


def entry_points():
    """The AOT export table: name -> (fn, example argument shapes)."""
    f32 = jnp.float32
    t = lambda *shape: jax.ShapeDtypeStruct(shape, f32)  # noqa: E731
    return {
        "tile_mma": (tile_mma, (t(BATCH, TILE, TILE),) * 3),
        "tile_group_mma": (
            tile_group_mma,
            (t(GROUPS, GROUP_K, TILE, TILE),) * 2,
        ),
        "dense_mm": (dense_mm, (t(DENSE_N, DENSE_N), t(DENSE_N, DENSE_N))),
    }
